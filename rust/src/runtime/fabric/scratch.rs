//! Per-lane scratch arena: every reusable buffer the interpreter's
//! forward pass and band kernels need, recycled through a bag so
//! steady-state serving does no per-image heap allocation in
//! GEMM/attention scratch. Scratch is always part of the
//! **per-replica mutable half** of a loaded model — replicas share one
//! immutable [`crate::runtime::ModelArtifact`], never an arena.
//!
//! A [`LaneScratch`] box is two disjoint halves that never alias:
//!
//! * [`PassScratch`] — the **forward pass** buffers (quantized tokens,
//!   residual stream, LayerNorm/QKV/attention/MLP intermediates, head
//!   pooling), held by whoever drives a whole image through the model:
//!   the pooled forward, a batch-grain band worker, or a pipeline stage.
//! * [`BandScratch`] — the **band kernel** buffers (GEMM band
//!   accumulator for the fused requant epilogue, LayerNorm centered
//!   sums, attention score/probability rows, softmax exps), used by one
//!   band of a parallel region — or, on the serial path, threaded
//!   directly into the kernels so a fully-serial forward touches no
//!   arena lock at all.
//!
//! Buffers only ever grow (`clear` + `resize` reuses capacity), and
//! boxes return to the bag when their holder finishes, so after a
//! warmup forward the arena's allocation count
//! ([`ScratchArena::allocs`]) and capacity footprint
//! ([`ScratchArena::footprint`]) are both flat — the zero-alloc
//! regression tests pin exactly that.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Reusable per-row softmax buffers (max-subtracted scores + exps) —
/// hoisted out of the per-row hot path.
#[derive(Default)]
pub struct SoftmaxScratch {
    pub(crate) sc: Vec<i32>,
    pub(crate) e: Vec<i32>,
}

impl SoftmaxScratch {
    pub(crate) fn new(t: usize) -> Self {
        Self { sc: vec![0i32; t], e: vec![0i32; t] }
    }

    /// Set both buffers to length `t`, reusing capacity. No clear():
    /// `softmax_row` overwrites every element before reading it.
    pub(crate) fn reset(&mut self, t: usize) {
        self.sc.resize(t, 0);
        self.e.resize(t, 0);
    }

    fn footprint(&self) -> usize {
        (self.sc.capacity() + self.e.capacity()) * std::mem::size_of::<i32>()
    }
}

/// Band-level kernel buffers: what one band of a parallel region (or the
/// serial kernel path) needs. All fields start empty and grow to their
/// steady-state size on first use.
#[derive(Default)]
pub struct BandScratch {
    /// GEMM i64 band accumulator — the fused requant epilogue maps it
    /// into the i32 output band right after the band's rows are computed.
    pub(crate) acc: Vec<i64>,
    /// LayerNorm centered sums `d*x[j] - sum(x)` for one token row.
    pub(crate) ln_c: Vec<i64>,
    /// Attention score row (one output token against all key tokens).
    pub(crate) scores: Vec<i64>,
    /// Attention probability row (requantized softmax output).
    pub(crate) prob: Vec<i32>,
    /// `R @ V` accumulator for one head's output slice.
    pub(crate) rv: Vec<i64>,
    /// Softmax working buffers for one score row.
    pub(crate) softmax: SoftmaxScratch,
}

impl BandScratch {
    fn footprint(&self) -> usize {
        (self.prob.capacity()) * std::mem::size_of::<i32>()
            + (self.acc.capacity()
                + self.ln_c.capacity()
                + self.scores.capacity()
                + self.rv.capacity())
                * std::mem::size_of::<i64>()
            + self.softmax.footprint()
    }
}

/// Whole-pass buffers, held by the driver of one image's forward (never
/// by band jobs, so they can be borrowed alongside a [`BandScratch`]).
#[derive(Default)]
pub struct PassScratch {
    /// Quantized input tokens.
    pub(crate) xq: Vec<i32>,
    /// Residual stream (int32, common scale). Taken out of the scratch
    /// for the duration of a pass (`mem::take`) so pipeline stages can
    /// carry the same buffer through bounded channels instead.
    pub(crate) x: Vec<i32>,
    /// LayerNorm output rows.
    pub(crate) n: Vec<i32>,
    /// Requantized fused QKV rows.
    pub(crate) qkv: Vec<i32>,
    /// Attention output rows.
    pub(crate) a_q: Vec<i32>,
    /// Requantized MLP hidden activations (GELU output).
    pub(crate) hdn: Vec<i32>,
    /// Head mean-pool accumulator.
    pub(crate) pooled: Vec<i64>,
}

impl PassScratch {
    fn footprint(&self) -> usize {
        (self.xq.capacity()
            + self.x.capacity()
            + self.n.capacity()
            + self.qkv.capacity()
            + self.a_q.capacity()
            + self.hdn.capacity())
            * std::mem::size_of::<i32>()
            + self.pooled.capacity() * std::mem::size_of::<i64>()
    }
}

/// One lane's worth of reusable interpreter buffers: a band half and a
/// pass half. The split lets a fully-serial forward borrow both halves
/// of one box simultaneously (pass buffers + kernel band buffers) with
/// zero arena locking — the batch-grain worker and every pipeline stage
/// run exactly that way.
#[derive(Default)]
pub struct LaneScratch {
    pub(crate) band: BandScratch,
    pub(crate) pass: PassScratch,
}

impl LaneScratch {
    /// Total bytes of capacity held across all buffers.
    fn footprint(&self) -> usize {
        self.band.footprint() + self.pass.footprint()
    }
}

/// A bag of recycled [`LaneScratch`] boxes shared by every handle to one
/// [`super::LanePool`].
pub(crate) struct ScratchArena {
    bag: Mutex<Vec<Box<LaneScratch>>>,
    /// Boxes ever allocated — flat once the pool is warmed up.
    created: AtomicUsize,
}

impl ScratchArena {
    pub(crate) fn new() -> Self {
        Self { bag: Mutex::new(Vec::new()), created: AtomicUsize::new(0) }
    }

    pub(crate) fn checkout(&self) -> Box<LaneScratch> {
        if let Some(s) = self.bag.lock().unwrap().pop() {
            return s;
        }
        self.created.fetch_add(1, Ordering::SeqCst);
        Box::<LaneScratch>::default()
    }

    pub(crate) fn restore(&self, s: Box<LaneScratch>) {
        self.bag.lock().unwrap().push(s);
    }

    pub(crate) fn allocs(&self) -> usize {
        self.created.load(Ordering::SeqCst)
    }

    /// Capacity bytes across the *idle* boxes in the bag. Deterministic
    /// whenever no forward is in flight (every box is back in the bag).
    pub(crate) fn footprint(&self) -> usize {
        self.bag.lock().unwrap().iter().map(|s| s.footprint()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_recycles_boxes() {
        let arena = ScratchArena::new();
        let mut a = arena.checkout();
        a.band.acc.resize(1024, 0);
        arena.restore(a);
        assert_eq!(arena.allocs(), 1);
        let fp = arena.footprint();
        assert!(fp >= 1024 * 8);
        // steady state: the same box cycles, nothing new is created and
        // no buffer regrows
        for _ in 0..10 {
            let mut b = arena.checkout();
            b.band.acc.clear();
            b.band.acc.resize(1024, 0);
            arena.restore(b);
        }
        assert_eq!(arena.allocs(), 1);
        assert_eq!(arena.footprint(), fp);
    }

    #[test]
    fn concurrent_checkouts_get_distinct_boxes() {
        let arena = ScratchArena::new();
        let a = arena.checkout();
        let b = arena.checkout();
        assert_eq!(arena.allocs(), 2);
        arena.restore(a);
        arena.restore(b);
        assert_eq!(arena.checkout().footprint(), 0);
        assert_eq!(arena.allocs(), 2);
    }

    #[test]
    fn softmax_reset_reuses_capacity() {
        let mut s = SoftmaxScratch::new(16);
        let cap = s.sc.capacity();
        s.reset(8);
        assert_eq!(s.sc.len(), 8);
        s.reset(16);
        assert_eq!(s.sc.capacity(), cap);
    }

    #[test]
    fn pass_and_band_halves_are_independently_borrowable() {
        // the serial forward relies on this split: pass buffers and band
        // buffers of ONE box borrowed mutably at the same time
        let mut s = LaneScratch::default();
        let LaneScratch { band, pass } = &mut s;
        band.acc.push(1);
        pass.x.push(2);
        assert_eq!((band.acc[0], pass.x[0]), (1, 2));
    }
}
