//! Per-lane scratch arena: every reusable buffer the interpreter's
//! forward pass and band kernels need, recycled through a bag so
//! steady-state serving does no per-image heap allocation in
//! GEMM/attention scratch.
//!
//! A [`LaneScratch`] box is checked out of the pool's [`ScratchArena`]
//! at two nesting levels that never alias:
//!
//! * the **forward pass** holds one box for its whole-pass buffers
//!   (quantized tokens, residual stream, GEMM accumulator, requantized
//!   intermediates, head pooling);
//! * each **band job** inside a parallel region checks out its own box
//!   for the per-row kernels (LayerNorm centered sums, attention
//!   score/probability rows, softmax exps).
//!
//! Buffers only ever grow (`clear` + `resize` reuses capacity), and
//! boxes return to the bag when their holder finishes, so after a
//! warmup forward the arena's allocation count
//! ([`ScratchArena::allocs`]) and capacity footprint
//! ([`ScratchArena::footprint`]) are both flat — the zero-alloc
//! regression tests pin exactly that.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Reusable per-row softmax buffers (max-subtracted scores + exps) —
/// hoisted out of the per-row hot path.
pub struct SoftmaxScratch {
    pub(crate) sc: Vec<i32>,
    pub(crate) e: Vec<i32>,
}

impl SoftmaxScratch {
    pub(crate) fn new(t: usize) -> Self {
        Self { sc: vec![0i32; t], e: vec![0i32; t] }
    }

    /// Set both buffers to length `t`, reusing capacity. No clear():
    /// `softmax_row` overwrites every element before reading it.
    pub(crate) fn reset(&mut self, t: usize) {
        self.sc.resize(t, 0);
        self.e.resize(t, 0);
    }

    fn footprint(&self) -> usize {
        (self.sc.capacity() + self.e.capacity()) * std::mem::size_of::<i32>()
    }
}

/// One lane's worth of reusable interpreter buffers. All fields start
/// empty and grow to their steady-state size on first use.
pub struct LaneScratch {
    // ---- band-level kernel buffers ----
    /// LayerNorm centered sums `d*x[j] - sum(x)` for one token row.
    pub(crate) ln_c: Vec<i64>,
    /// Attention score row (one output token against all key tokens).
    pub(crate) scores: Vec<i64>,
    /// Attention probability row (requantized softmax output).
    pub(crate) prob: Vec<i32>,
    /// `R @ V` accumulator for one head's output slice.
    pub(crate) rv: Vec<i64>,
    /// Softmax working buffers for one score row.
    pub(crate) softmax: SoftmaxScratch,
    // ---- forward-pass buffers (held by the pass, not by band jobs) ----
    /// Quantized input tokens.
    pub(crate) xq: Vec<i32>,
    /// Residual stream (int32, common scale).
    pub(crate) x: Vec<i32>,
    /// LayerNorm output rows.
    pub(crate) n: Vec<i32>,
    /// Requantized fused QKV rows.
    pub(crate) qkv: Vec<i32>,
    /// Attention output rows.
    pub(crate) a_q: Vec<i32>,
    /// Requantized MLP hidden activations (GELU output).
    pub(crate) hdn: Vec<i32>,
    /// GEMM i64 accumulator, reused by every matmul in the pass.
    pub(crate) acc: Vec<i64>,
    /// Head mean-pool accumulator.
    pub(crate) pooled: Vec<i64>,
}

impl Default for LaneScratch {
    fn default() -> Self {
        Self {
            ln_c: Vec::new(),
            scores: Vec::new(),
            prob: Vec::new(),
            rv: Vec::new(),
            softmax: SoftmaxScratch { sc: Vec::new(), e: Vec::new() },
            xq: Vec::new(),
            x: Vec::new(),
            n: Vec::new(),
            qkv: Vec::new(),
            a_q: Vec::new(),
            hdn: Vec::new(),
            acc: Vec::new(),
            pooled: Vec::new(),
        }
    }
}

impl LaneScratch {
    /// Total bytes of capacity held across all buffers.
    fn footprint(&self) -> usize {
        let i32s = self.prob.capacity()
            + self.xq.capacity()
            + self.x.capacity()
            + self.n.capacity()
            + self.qkv.capacity()
            + self.a_q.capacity()
            + self.hdn.capacity();
        let i64s = self.ln_c.capacity()
            + self.scores.capacity()
            + self.rv.capacity()
            + self.acc.capacity()
            + self.pooled.capacity();
        i32s * std::mem::size_of::<i32>()
            + i64s * std::mem::size_of::<i64>()
            + self.softmax.footprint()
    }
}

/// A bag of recycled [`LaneScratch`] boxes shared by every handle to one
/// [`super::LanePool`].
pub(crate) struct ScratchArena {
    bag: Mutex<Vec<Box<LaneScratch>>>,
    /// Boxes ever allocated — flat once the pool is warmed up.
    created: AtomicUsize,
}

impl ScratchArena {
    pub(crate) fn new() -> Self {
        Self { bag: Mutex::new(Vec::new()), created: AtomicUsize::new(0) }
    }

    pub(crate) fn checkout(&self) -> Box<LaneScratch> {
        if let Some(s) = self.bag.lock().unwrap().pop() {
            return s;
        }
        self.created.fetch_add(1, Ordering::SeqCst);
        Box::<LaneScratch>::default()
    }

    pub(crate) fn restore(&self, s: Box<LaneScratch>) {
        self.bag.lock().unwrap().push(s);
    }

    pub(crate) fn allocs(&self) -> usize {
        self.created.load(Ordering::SeqCst)
    }

    /// Capacity bytes across the *idle* boxes in the bag. Deterministic
    /// whenever no forward is in flight (every box is back in the bag).
    pub(crate) fn footprint(&self) -> usize {
        self.bag.lock().unwrap().iter().map(|s| s.footprint()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_recycles_boxes() {
        let arena = ScratchArena::new();
        let mut a = arena.checkout();
        a.acc.resize(1024, 0);
        arena.restore(a);
        assert_eq!(arena.allocs(), 1);
        let fp = arena.footprint();
        assert!(fp >= 1024 * 8);
        // steady state: the same box cycles, nothing new is created and
        // no buffer regrows
        for _ in 0..10 {
            let mut b = arena.checkout();
            b.acc.clear();
            b.acc.resize(1024, 0);
            arena.restore(b);
        }
        assert_eq!(arena.allocs(), 1);
        assert_eq!(arena.footprint(), fp);
    }

    #[test]
    fn concurrent_checkouts_get_distinct_boxes() {
        let arena = ScratchArena::new();
        let a = arena.checkout();
        let b = arena.checkout();
        assert_eq!(arena.allocs(), 2);
        arena.restore(a);
        arena.restore(b);
        assert_eq!(arena.checkout().footprint(), 0);
        assert_eq!(arena.allocs(), 2);
    }

    #[test]
    fn softmax_reset_reuses_capacity() {
        let mut s = SoftmaxScratch::new(16);
        let cap = s.sc.capacity();
        s.reset(8);
        assert_eq!(s.sc.len(), 8);
        s.reset(16);
        assert_eq!(s.sc.capacity(), cap);
    }
}
