//! The compute *fabric* behind the interpreter backend: a lane pool of
//! `std::thread` workers plus cache-blocked integer GEMM kernels.
//!
//! HG-PIPE's throughput comes from spatially unrolling the ViT dataflow
//! and running many coupled lanes in parallel rather than time-sharing one
//! sequential engine. This module is the software twin of that idea for
//! the pure-rust interpreter:
//!
//! * [`LanePool`] — work partitioning at two grains: whole batch lanes
//!   (one image per worker, the coordinator's dispatch width) and row
//!   bands inside a single image (per-token / per-head parallelism in
//!   LayerNorm, GEMM and attention).
//! * [`gemm::PackedGemm`] — the blocked, output-stationary i64-accumulate
//!   matmul with the weight matrix re-packed into column panels once at
//!   bundle load.
//!
//! Everything here is bit-exactness-preserving by construction: lanes
//! write disjoint output rows and every accumulator sums the same i64
//! terms in the same ascending-k order as the scalar reference, so the
//! golden fixture holds at any lane count.
//!
//! The pool spawns scoped `std::thread` workers per parallel region (no
//! external thread-pool crates in this offline environment). Spawn cost
//! is amortized at batch grain (one region per dispatch); at row grain it
//! pays off for larger token counts — a persistent worker set plus SIMD
//! inner loops are the next step (see ROADMAP).

pub mod gemm;

/// Worker-lane configuration for the interpreter fabric.
///
/// The lane count comes from the `HGPIPE_LANES` environment variable (or
/// the `--lanes` CLI flag, which sets it) via [`LanePool::from_env`];
/// `lanes == 1` means fully serial execution on the caller thread.
#[derive(Debug, Clone, Copy)]
pub struct LanePool {
    lanes: usize,
}

impl LanePool {
    /// A pool with an explicit lane count (clamped to at least 1).
    pub fn new(lanes: usize) -> Self {
        Self { lanes: lanes.max(1) }
    }

    /// A single-lane pool: every region runs inline on the caller.
    pub fn serial() -> Self {
        Self { lanes: 1 }
    }

    /// Lane count from `HGPIPE_LANES`, falling back to the machine's
    /// available parallelism (1 if that is unknown). A parsed value of 0
    /// clamps to 1 (serial), matching the CLI's `--lanes` floor rather
    /// than silently meaning "all cores"; an unparseable value warns on
    /// stderr before falling back, so a typo'd env var is never a silent
    /// misconfiguration.
    pub fn from_env() -> Self {
        let default = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let lanes = match std::env::var("HGPIPE_LANES") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) => n.max(1),
                Err(_) => {
                    eprintln!(
                        "warning: HGPIPE_LANES='{v}' is not a lane count; \
                         using available parallelism"
                    );
                    default()
                }
            },
            Err(_) => default(),
        };
        Self::new(lanes)
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Split `data` into contiguous bands of whole `chunk`-sized rows —
    /// one band per lane — and run `f(first_row_index, band)` on each
    /// band, lane 0 on the caller thread and the rest on scoped workers.
    ///
    /// The split is deterministic (the first `rows % lanes` bands take one
    /// extra row) but the result must not depend on it: bands are disjoint
    /// `&mut` sub-slices, so any `f` that computes a row purely from its
    /// global row index and shared read-only state is bit-exact at every
    /// lane count.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0, "chunk size must be positive");
        assert_eq!(data.len() % chunk, 0, "data length must be a multiple of chunk");
        let rows = data.len() / chunk;
        let lanes = self.lanes.min(rows.max(1));
        if lanes <= 1 {
            f(0, data);
            return;
        }
        let base = rows / lanes;
        let extra = rows % lanes;
        std::thread::scope(|s| {
            let f = &f;
            let mut rest: &mut [T] = data;
            let mut row0 = 0usize;
            let mut own: Option<(usize, &mut [T])> = None;
            for lane in 0..lanes {
                let take = base + usize::from(lane < extra);
                // move `rest` out before splitting so the band keeps the
                // full input lifetime (required by the scoped spawns)
                let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * chunk);
                rest = tail;
                let start = row0;
                row0 += take;
                if lane == 0 {
                    own = Some((start, band));
                } else {
                    s.spawn(move || f(start, band));
                }
            }
            if let Some((start, band)) = own {
                f(start, band);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let mut v = vec![0u32; 12];
        LanePool::serial().par_chunks_mut(&mut v, 3, |r0, band| {
            assert_eq!(r0, 0);
            assert_eq!(band.len(), 12);
            for x in band.iter_mut() {
                *x = 7;
            }
        });
        assert!(v.iter().all(|&x| x == 7));
    }

    #[test]
    fn bands_cover_all_rows_exactly_once() {
        // odd split: 10 rows over 3 lanes -> bands of 4, 3, 3
        for lanes in 1..=8 {
            let mut v = vec![0usize; 10 * 4];
            let calls = AtomicUsize::new(0);
            LanePool::new(lanes).par_chunks_mut(&mut v, 4, |r0, band| {
                calls.fetch_add(1, Ordering::SeqCst);
                for (i, row) in band.chunks_exact_mut(4).enumerate() {
                    for x in row.iter_mut() {
                        *x = r0 + i + 1; // global row index, 1-based
                    }
                }
            });
            for (r, row) in v.chunks_exact(4).enumerate() {
                assert!(row.iter().all(|&x| x == r + 1), "lanes={lanes} row={r}");
            }
            assert!(calls.load(Ordering::SeqCst) <= lanes.min(10));
        }
    }

    #[test]
    fn more_lanes_than_rows_is_fine() {
        let mut v = vec![0u8; 2 * 5];
        LanePool::new(16).par_chunks_mut(&mut v, 5, |_, band| {
            for x in band.iter_mut() {
                *x = 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn empty_data_is_a_noop() {
        let mut v: Vec<i64> = Vec::new();
        LanePool::new(4).par_chunks_mut(&mut v, 8, |_, band| {
            assert!(band.is_empty());
        });
    }

    #[test]
    fn new_clamps_zero_lanes() {
        assert_eq!(LanePool::new(0).lanes(), 1);
        assert!(LanePool::from_env().lanes() >= 1);
    }
}
