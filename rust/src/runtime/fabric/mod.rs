//! The compute *fabric* behind the interpreter backend: a **persistent**
//! lane pool of parked `std::thread` workers, a per-lane scratch arena,
//! and register-blocked integer GEMM kernels.
//!
//! HG-PIPE's throughput comes from spatially unrolling the ViT dataflow
//! and keeping every compute unit busy — no per-region setup cost, no
//! memory traffic that the dataflow does not require. This module is the
//! software twin of that idea for the pure-rust interpreter:
//!
//! * [`LanePool`] — a shared handle to a set of workers created **once**
//!   (when a model loads) and parked on a condvar between parallel
//!   regions. A region splits its output into contiguous row bands — one
//!   per lane — queues one job per worker band, runs the first band on
//!   the caller thread, and blocks until the region's latch opens. The
//!   pre-PR-3 fabric spawned scoped threads per region; at token-row
//!   grain on small models the spawn cost rivaled the work itself.
//! * [`scratch::LaneScratch`] / the pool's arena — every checkout-able
//!   buffer the forward pass and the band kernels need (GEMM
//!   accumulators, attention score/probability rows, LayerNorm centered
//!   sums). Buffers are recycled through a bag, so steady-state serving
//!   performs **no per-image heap allocation** in GEMM/attention scratch
//!   (ME-ViT's single-load / buffer-reuse discipline, in software).
//! * [`gemm::PackedGemm`] — the panel-packed integer GEMM with a 4-row ×
//!   8-wide register-blocked microkernel and a per-row activation-density
//!   fallback to the zero-skip scalar path.
//!
//! Everything here is bit-exactness-preserving by construction: lanes
//! write disjoint output rows and every accumulator sums the same i64
//! terms in the same ascending-k order as the scalar reference, so the
//! golden fixture holds at any lane count.
//!
//! ## Lifecycle
//!
//! `LanePool` is a cheap-to-clone shared handle (`Arc` inside); all
//! clones drive the same workers and the same scratch arena. When the
//! last handle drops, the pool flags shutdown, wakes every parked
//! worker, and **joins** them — model unload never leaks threads (the
//! lifecycle test asserts this via [`LanePool::live_workers`]). Under
//! multi-executor scale-out (`RuntimeConfig::replicas`) the fabric is
//! the **per-replica mutable half** of a loaded model: every replica
//! borrows the same immutable [`crate::runtime::ModelArtifact`]
//! (weights, packed panels, LUTs) but owns its own pool and scratch —
//! fabrics are never shared across replicas, mirroring one engine per
//! feeder over a single-load weight store.
//!
//! ## Lane count
//!
//! An explicit count (`--lanes`, threaded through
//! [`crate::runtime::RuntimeConfig`]) wins; otherwise
//! [`LanePool::from_env`] reads the `HGPIPE_LANES` environment variable
//! (read-only — nothing in this crate mutates it), falling back to the
//! machine's available parallelism. `lanes == 1` parks no workers and
//! runs every region inline on the caller.

pub mod gemm;
pub mod scratch;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

pub use scratch::{BandScratch, LaneScratch, PassScratch};
use scratch::ScratchArena;

use crate::runtime::kernels::{self, Kernels};

/// How a kernel's output-row bands execute: inline with an explicitly
/// provided band scratch, or spread across a [`LanePool`]'s lanes.
///
/// The serial variant is what lets the batch-grain worker bands and the
/// pipeline's resident stages run a whole per-image forward with **zero
/// locking**: the kernels draw their band buffers straight from the
/// caller's [`BandScratch`] instead of checking a box out of the arena
/// per parallel region. Both variants are bit-exact — the banding never
/// changes a kernel's per-row arithmetic.
///
/// An `Exec` also carries the [`Kernels`] vtable the ops layer drives
/// its inner loops through (see [`crate::runtime::kernels`]): pool
/// execs inherit their pool's backend, serial execs take one
/// explicitly, so lane-parallel and resident-pipeline forwards hit the
/// same vectorized code paths.
pub struct Exec<'a> {
    kernels: &'static Kernels,
    inner: ExecInner<'a>,
}

enum ExecInner<'a> {
    /// Fully serial on the caller thread, band buffers provided
    /// explicitly — no arena traffic, no job-queue traffic.
    Serial(&'a mut BandScratch),
    /// Bands dispatched to the pool's parked workers
    /// (via [`LanePool::par_chunks_mut`]).
    Pool(&'a LanePool),
}

impl<'a> Exec<'a> {
    /// A serial exec over the caller's band scratch, driving the given
    /// kernel backend.
    pub fn serial(band: &'a mut BandScratch, kernels: &'static Kernels) -> Self {
        Self { kernels, inner: ExecInner::Serial(band) }
    }

    /// A pool-dispatched exec; inherits the pool's kernel backend.
    pub fn pool(pool: &'a LanePool) -> Self {
        Self { kernels: pool.kernels(), inner: ExecInner::Pool(pool) }
    }

    /// The kernel backend this exec's band closures should drive.
    pub(crate) fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Run `f(band_scratch, first_row_index, band)` over `data` split
    /// into bands of whole `chunk`-sized rows: one band inline (serial),
    /// or one per lane (pool). Same banding contract as
    /// [`LanePool::par_chunks_mut`]: bands are disjoint, every row is
    /// visited exactly once, and any `f` that computes a row purely from
    /// its global row index, its own scratch and shared read-only state
    /// is bit-exact under both variants.
    pub(crate) fn run<T, F>(&mut self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(&mut BandScratch, usize, &mut [T]) + Sync,
    {
        match &mut self.inner {
            ExecInner::Serial(band) => {
                // same hard asserts as par_chunks_mut, so a malformed
                // caller fails identically at every lane count (a
                // debug-only check would let release builds silently
                // drop a trailing partial row in serial mode)
                assert!(chunk > 0, "chunk size must be positive");
                assert_eq!(data.len() % chunk, 0, "data length must be a multiple of chunk");
                f(&mut **band, 0, data)
            }
            ExecInner::Pool(pool) => {
                pool.par_chunks_mut(data, chunk, |s, r0, b| f(&mut s.band, r0, b))
            }
        }
    }
}

/// Count of currently-live fabric worker threads across the process.
/// Incremented before a worker spawns, decremented when its thread
/// exits; [`LanePool`]'s drop joins workers, so after the last handle to
/// a pool drops its workers are guaranteed to have been subtracted.
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// A queued band job: the type-erased band closure plus the region latch
/// it must open on completion.
struct Job {
    task: Task,
    latch: Arc<RegionLatch>,
}

/// The band closure with its borrow lifetime erased. SAFETY: the only
/// producer is [`LanePool::par_chunks_mut`], which blocks until the
/// region latch reports every job done (even if the caller's own band
/// panics, via `RegionGuard`), so the borrows a task captures always
/// outlive its execution.
type Task = Box<dyn FnOnce(&mut LaneScratch) + Send + 'static>;

/// One parallel region's completion state: open when every queued job
/// has run. A panicking band parks its payload here so the region caller
/// can re-raise the *original* panic (message, location) instead of a
/// generic one.
struct RegionLatch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl RegionLatch {
    fn new(jobs: usize) -> Self {
        Self {
            remaining: Mutex::new(jobs),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
            payload: Mutex::new(None),
        }
    }

    /// Block until every job has completed. Idempotent — a second wait
    /// returns immediately.
    fn wait(&self) {
        let mut n = self.remaining.lock().unwrap();
        while *n > 0 {
            n = self.done.wait(n).unwrap();
        }
    }

    fn complete_one(&self) {
        let mut n = self.remaining.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }
}

/// Waits out the region latch even when the caller's own band panics, so
/// worker jobs never outlive the borrows they captured.
struct RegionGuard<'a> {
    latch: &'a RegionLatch,
}

impl Drop for RegionGuard<'_> {
    fn drop(&mut self) {
        self.latch.wait();
    }
}

/// The state workers and dispatching handles share.
struct PoolShared {
    queue: Mutex<JobQueue>,
    wake: Condvar,
    arena: ScratchArena,
}

struct JobQueue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

thread_local! {
    /// Identity (shared-state address) of the pool this thread serves as
    /// a worker; 0 on every other thread. [`LanePool::par_chunks_mut`]
    /// consults it so a region dispatched from a pool's *own* worker
    /// runs inline instead of queueing jobs the blocked worker would
    /// deadlock waiting for.
    static WORKER_OF: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn worker_loop(shared: Arc<PoolShared>) {
    // decrement happens on every exit path (including unwinding), and
    // the pool's drop joins the thread, so the counter is exact after
    // the last handle drops
    struct Live;
    impl Drop for Live {
        fn drop(&mut self) {
            LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _live = Live;
    WORKER_OF.with(|w| w.set(Arc::as_ptr(&shared) as usize));

    // the worker owns one scratch box for its whole life (returned to
    // the bag at shutdown), so serving a job touches the arena lock not
    // at all — bands contend only on the job queue
    let mut scratch = shared.arena.checkout();
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(job) = q.jobs.pop_front() {
            drop(q);
            let Job { task, latch } = job;
            // contain a panicking band: the region caller re-raises after
            // its latch opens, and the worker survives to serve the next
            // region (a poisoned fabric would wedge the whole model)
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(&mut scratch)));
            if let Err(p) = result {
                latch.panicked.store(true, Ordering::SeqCst);
                let mut slot = latch.payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p); // first panic wins; the rest are dropped
                }
            }
            latch.complete_one();
            q = shared.queue.lock().unwrap();
        } else if q.shutdown {
            drop(q);
            shared.arena.restore(scratch);
            return;
        } else {
            q = shared.wake.wait(q).unwrap();
        }
    }
}

/// Owner of the worker threads; dropped when the last [`LanePool`]
/// handle goes away.
struct PoolInner {
    lanes: usize,
    kernels: &'static Kernels,
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.wake.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Shared handle to a persistent worker-lane fabric.
///
/// Cloning is cheap and shares the workers and the scratch arena;
/// dropping the last clone shuts the workers down deterministically.
/// Dispatch is thread-safe: multiple threads may run parallel regions on
/// one pool concurrently (jobs interleave on the shared queue).
#[derive(Clone)]
pub struct LanePool {
    inner: Arc<PoolInner>,
}

impl std::fmt::Debug for LanePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LanePool({} lanes, {} workers, {} kernels)",
            self.inner.lanes,
            self.inner.workers.len(),
            self.inner.kernels.name
        )
    }
}

impl LanePool {
    /// A pool with an explicit lane count (clamped to at least 1). Parks
    /// `lanes - 1` workers immediately; lane 0 is always the caller. The
    /// kernel backend is resolved once here via
    /// [`kernels::from_env`] (auto-detect unless `HGPIPE_KERNELS`
    /// forces one); use [`Self::with_kernels`] for an explicit backend.
    pub fn new(lanes: usize) -> Self {
        Self::with_kernels(lanes, kernels::from_env())
    }

    /// A pool pinned to an explicit kernel backend. Every band closure
    /// dispatched through this pool (and every [`Exec::pool`] built on
    /// it) drives its inner loops through this vtable.
    pub fn with_kernels(lanes: usize, kernels: &'static Kernels) -> Self {
        let lanes = lanes.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(JobQueue { jobs: VecDeque::new(), shutdown: false }),
            wake: Condvar::new(),
            arena: ScratchArena::new(),
        });
        let mut workers = Vec::with_capacity(lanes - 1);
        for i in 1..lanes {
            let s = shared.clone();
            LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
            let handle = std::thread::Builder::new()
                .name(format!("hgpipe-lane-{i}"))
                .spawn(move || worker_loop(s));
            match handle {
                Ok(h) => workers.push(h),
                Err(e) => {
                    LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
                    // shut down + join the lanes already spawned before
                    // propagating, so a failed spawn never leaks parked
                    // workers for the process lifetime
                    drop(PoolInner { lanes, kernels, shared, workers });
                    panic!("failed to spawn fabric worker lane {i}: {e}");
                }
            }
        }
        Self { inner: Arc::new(PoolInner { lanes, kernels, shared, workers }) }
    }

    /// A single-lane pool: every region runs inline on the caller, no
    /// worker threads. Still owns a scratch arena, so serial forwards
    /// recycle their buffers too.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// Lane count from `HGPIPE_LANES` (read-only — the CLI's `--lanes`
    /// is threaded through [`crate::runtime::RuntimeConfig`] instead of
    /// mutating the environment), falling back to the machine's
    /// available parallelism (1 if that is unknown). A parsed value of 0
    /// clamps to 1 (serial), matching the CLI's `--lanes` floor rather
    /// than silently meaning "all cores"; an unparseable value warns on
    /// stderr before falling back, so a typo'd env var is never a silent
    /// misconfiguration.
    pub fn from_env() -> Self {
        Self::new(Self::lanes_from_env())
    }

    /// The lane count [`Self::from_env`] would use, without building a
    /// pool.
    pub fn lanes_from_env() -> usize {
        let default = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        match std::env::var("HGPIPE_LANES") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(n) => n.max(1),
                Err(_) => {
                    eprintln!(
                        "warning: HGPIPE_LANES='{v}' is not a lane count; \
                         using available parallelism"
                    );
                    default()
                }
            },
            Err(_) => default(),
        }
    }

    pub fn lanes(&self) -> usize {
        self.inner.lanes
    }

    /// The kernel backend this pool was built with (fixed for the
    /// pool's lifetime — backends are selected once at model load).
    pub fn kernels(&self) -> &'static Kernels {
        self.inner.kernels
    }

    /// Process-wide count of live fabric worker threads. After the last
    /// handle to a pool drops this excludes that pool's workers (drop
    /// joins them) — the lifecycle tests pin "no leaked threads" on it.
    pub fn live_workers() -> usize {
        LIVE_WORKERS.load(Ordering::SeqCst)
    }

    /// Number of scratch boxes this pool's arena has ever allocated.
    /// Flat across steady-state forwards — the zero-alloc regression
    /// tests assert exactly that.
    pub fn scratch_allocs(&self) -> usize {
        self.inner.shared.arena.allocs()
    }

    /// Total bytes of buffer capacity held by idle scratch boxes in the
    /// arena. Once warmed up, repeated forwards leave this unchanged (no
    /// buffer regrows).
    pub fn scratch_footprint(&self) -> usize {
        self.inner.shared.arena.footprint()
    }

    /// Check a scratch box out of the arena (recycled if one is idle,
    /// freshly allocated otherwise). The forward pass holds one for its
    /// whole-pass buffers while band jobs check out their own.
    pub(crate) fn checkout_scratch(&self) -> Box<LaneScratch> {
        self.inner.shared.arena.checkout()
    }

    /// Return a scratch box to the arena for reuse.
    pub(crate) fn restore_scratch(&self, s: Box<LaneScratch>) {
        self.inner.shared.arena.restore(s);
    }

    /// Split `data` into contiguous bands of whole `chunk`-sized rows —
    /// one band per lane — and run `f(scratch, first_row_index, band)` on
    /// each band: lane 0 on the caller thread, the rest on the parked
    /// workers. Blocks until every band completes.
    ///
    /// The split is deterministic (the first `rows % lanes` bands take one
    /// extra row) but the result must not depend on it: bands are disjoint
    /// `&mut` sub-slices, so any `f` that computes a row purely from its
    /// global row index, its own scratch and shared read-only state is
    /// bit-exact at every lane count.
    ///
    /// If a band panics, the remaining bands still run to completion and
    /// the panic is re-raised on the caller once the region is quiescent
    /// (workers stay parked and reusable).
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(&mut LaneScratch, usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0, "chunk size must be positive");
        assert_eq!(data.len() % chunk, 0, "data length must be a multiple of chunk");
        let rows = data.len() / chunk;
        let lanes = self.inner.lanes.min(rows.max(1));
        let shared = &self.inner.shared;
        // a region dispatched from one of this pool's own workers must
        // not queue jobs and wait: the waiting worker is a lane the jobs
        // may need, and a fully-busy fabric would deadlock. Run inline —
        // the caller already *is* a parallel lane of an outer region.
        let on_own_worker = WORKER_OF.with(|w| w.get()) == Arc::as_ptr(shared) as usize;
        if lanes <= 1 || on_own_worker {
            let mut s = shared.arena.checkout();
            f(&mut s, 0, data);
            shared.arena.restore(s);
            return;
        }

        let base = rows / lanes;
        let extra = rows % lanes;
        let latch = Arc::new(RegionLatch::new(lanes - 1));
        let mut own: Option<(usize, &mut [T])> = None;
        {
            let fref = &f;
            let mut q = shared.queue.lock().unwrap();
            let mut rest: &mut [T] = data;
            let mut row0 = 0usize;
            for lane in 0..lanes {
                let take = base + usize::from(lane < extra);
                // move `rest` out before splitting so the band keeps the
                // full input lifetime
                let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * chunk);
                rest = tail;
                let start = row0;
                row0 += take;
                if lane == 0 {
                    own = Some((start, band));
                } else {
                    let task: Box<dyn FnOnce(&mut LaneScratch) + Send + '_> =
                        Box::new(move |s| fref(s, start, band));
                    // SAFETY: erase the borrow lifetime so the job can sit
                    // on the 'static queue. The RegionGuard below blocks
                    // this frame until the latch opens, i.e. until every
                    // queued job has finished running — the captured
                    // borrows (`fref`, `band`) strictly outlive all use.
                    let task = unsafe {
                        std::mem::transmute::<Box<dyn FnOnce(&mut LaneScratch) + Send + '_>, Task>(
                            task,
                        )
                    };
                    q.jobs.push_back(Job { task, latch: latch.clone() });
                }
            }
        }
        shared.wake.notify_all();

        {
            let _complete = RegionGuard { latch: &latch };
            if let Some((start, band)) = own {
                let mut s = shared.arena.checkout();
                f(&mut s, start, band);
                shared.arena.restore(s);
            }
        } // guard drops: wait for every worker band

        if latch.panicked.load(Ordering::SeqCst) {
            // re-raise the original panic (message + location) when a
            // band parked it; the generic message is only a fallback
            if let Some(p) = latch.payload.lock().unwrap().take() {
                std::panic::resume_unwind(p);
            }
            panic!("fabric worker lane panicked; parallel region is incomplete");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let mut v = vec![0u32; 12];
        LanePool::serial().par_chunks_mut(&mut v, 3, |_s, r0, band| {
            assert_eq!(r0, 0);
            assert_eq!(band.len(), 12);
            for x in band.iter_mut() {
                *x = 7;
            }
        });
        assert!(v.iter().all(|&x| x == 7));
    }

    #[test]
    fn bands_cover_all_rows_exactly_once() {
        // odd split: 10 rows over 3 lanes -> bands of 4, 3, 3
        for lanes in 1..=8 {
            let pool = LanePool::new(lanes);
            let mut v = vec![0usize; 10 * 4];
            let calls = AtomicUsize::new(0);
            pool.par_chunks_mut(&mut v, 4, |_s, r0, band| {
                calls.fetch_add(1, Ordering::SeqCst);
                for (i, row) in band.chunks_exact_mut(4).enumerate() {
                    for x in row.iter_mut() {
                        *x = r0 + i + 1; // global row index, 1-based
                    }
                }
            });
            for (r, row) in v.chunks_exact(4).enumerate() {
                assert!(row.iter().all(|&x| x == r + 1), "lanes={lanes} row={r}");
            }
            assert!(calls.load(Ordering::SeqCst) <= lanes.min(10));
        }
    }

    #[test]
    fn pool_is_reusable_across_many_regions() {
        // the same parked workers serve every region — no spawn per call
        let pool = LanePool::new(4);
        for round in 0..50usize {
            let mut v = vec![0usize; 16];
            pool.par_chunks_mut(&mut v, 1, |_s, r0, band| {
                for (i, x) in band.iter_mut().enumerate() {
                    *x = round + r0 + i;
                }
            });
            for (i, &x) in v.iter().enumerate() {
                assert_eq!(x, round + i, "round {round}");
            }
        }
    }

    #[test]
    fn more_lanes_than_rows_is_fine() {
        let mut v = vec![0u8; 2 * 5];
        LanePool::new(16).par_chunks_mut(&mut v, 5, |_s, _, band| {
            for x in band.iter_mut() {
                *x = 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn empty_data_is_a_noop() {
        let mut v: Vec<i64> = Vec::new();
        LanePool::new(4).par_chunks_mut(&mut v, 8, |_s, _, band| {
            assert!(band.is_empty());
        });
    }

    #[test]
    fn new_clamps_zero_lanes() {
        assert_eq!(LanePool::new(0).lanes(), 1);
        assert!(LanePool::lanes_from_env() >= 1);
    }

    #[test]
    fn clones_share_workers_and_arena() {
        let pool = LanePool::new(3);
        let clone = pool.clone();
        let mut v = vec![0u32; 9];
        clone.par_chunks_mut(&mut v, 3, |_s, _, band| band.fill(1));
        assert!(v.iter().all(|&x| x == 1));
        assert_eq!(pool.scratch_allocs(), clone.scratch_allocs());
    }

    #[test]
    fn concurrent_regions_from_two_threads() {
        let pool = LanePool::new(4);
        std::thread::scope(|sc| {
            for t in 0..2usize {
                let pool = pool.clone();
                sc.spawn(move || {
                    for _ in 0..20 {
                        let mut v = vec![0usize; 12];
                        pool.par_chunks_mut(&mut v, 2, |_s, r0, band| {
                            for (i, row) in band.chunks_exact_mut(2).enumerate() {
                                row.fill(t * 100 + r0 + i);
                            }
                        });
                        for (r, row) in v.chunks_exact(2).enumerate() {
                            assert!(row.iter().all(|&x| x == t * 100 + r), "t={t} r={r}");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn drop_after_use_does_not_hang_and_clone_keeps_workers() {
        // exact live_workers() counting lives in tests/fabric_lifecycle.rs,
        // which serializes its tests (the counter is process-wide and unit
        // tests here run concurrently); this test pins the behavior: a
        // clone keeps the fabric serviceable after the original drops, and
        // the final drop joins (returns) rather than leaking or hanging
        let pool = LanePool::new(5);
        let mut v = vec![0u8; 10];
        pool.par_chunks_mut(&mut v, 1, |_s, _, band| band.fill(1));
        assert!(v.iter().all(|&x| x == 1));
        let clone = pool.clone();
        drop(pool);
        let mut w = vec![0u8; 10];
        clone.par_chunks_mut(&mut w, 1, |_s, _, band| band.fill(2));
        assert!(w.iter().all(|&x| x == 2));
        drop(clone);
    }

    #[test]
    fn worker_band_panic_propagates_with_payload_and_pool_survives() {
        let pool = LanePool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut v = vec![0usize; 6];
            pool.par_chunks_mut(&mut v, 1, |_s, r0, _band| {
                if r0 > 0 {
                    panic!("injected band failure");
                }
            });
        }));
        // the ORIGINAL panic payload is re-raised, not a generic shim
        let payload = result.expect_err("panic must reach the region caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "injected band failure");
        // the fabric is still serviceable afterwards
        let mut v = vec![0usize; 6];
        pool.par_chunks_mut(&mut v, 1, |_s, r0, band| band.fill(r0));
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn nested_dispatch_from_a_band_runs_inline_without_deadlock() {
        let pool = LanePool::new(3);
        let nested = pool.clone();
        let mut v = vec![0usize; 9];
        pool.par_chunks_mut(&mut v, 3, |_s, r0, band| {
            // re-entering the same pool from a band (worker lanes detect
            // their own pool and run inline; the caller lane re-enters
            // normally) must complete, not wedge the fabric
            let mut inner = vec![0usize; 4];
            nested.par_chunks_mut(&mut inner, 1, |_s2, i0, b| {
                for (j, x) in b.iter_mut().enumerate() {
                    *x = i0 + j + 1;
                }
            });
            assert_eq!(inner, vec![1, 2, 3, 4]);
            for (i, row) in band.chunks_exact_mut(3).enumerate() {
                row.fill(r0 + i + 1);
            }
        });
        for (r, row) in v.chunks_exact(3).enumerate() {
            assert!(row.iter().all(|&x| x == r + 1), "row {r}");
        }
    }
}
