//! PJRT backend: load `artifacts/*.hlo.txt` (emitted by the python AOT
//! pipeline) onto the CPU PJRT client and execute them from the serving
//! hot path. Python is never involved at request time.
//!
//! Compiled only with `--features pjrt`. The feature resolves the `xla`
//! dependency from the in-repo stub crate by default (type-checks the
//! integration, errors at runtime); point it at a real binding to run.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::artifacts::{ArtifactInfo, Manifest};
use crate::runtime::{ExecStats, Executor, LoadedModel};

/// A compiled, ready-to-run computation.
pub struct Executable {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
    pub compile_ms: f64,
    /// Cumulative execution statistics (guarded; executions are serialized
    /// per executable by the PJRT CPU client anyway).
    stats: Mutex<ExecStats>,
}

impl Executable {
    /// Run the computation on a flat f32 input of the artifact's shape.
    /// Returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        let expected: usize = self.info.input_shape.iter().product();
        anyhow::ensure!(
            input.len() == expected,
            "input length {} != shape {:?}",
            input.len(),
            self.info.input_shape
        );
        let dims: Vec<i64> = self.info.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        {
            let mut s = self.stats.lock().unwrap();
            s.executions += 1;
            s.total_ms += ms;
        }
        // python lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// The PJRT engine: one CPU client + a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    pub fn cpu() -> crate::Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()?, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&self, info: &ArtifactInfo) -> crate::Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&info.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            info.path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let executable = std::sync::Arc::new(Executable {
            info: info.clone(),
            exe,
            compile_ms,
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache.lock().unwrap().insert(info.name.clone(), executable.clone());
        Ok(executable)
    }
}

/// Load an HLO text file directly (no manifest) — used by tests.
pub fn load_hlo_text(
    engine: &Engine,
    path: &Path,
    input_shape: Vec<usize>,
    output_shape: Vec<usize>,
) -> crate::Result<std::sync::Arc<Executable>> {
    let info = ArtifactInfo {
        name: path.display().to_string(),
        path: path.to_path_buf(),
        input_shape,
        output_shape,
        model: "adhoc".into(),
        precision: "?".into(),
    };
    engine.load(&info)
}

/// [`Executor`] adapter around a compiled artifact.
struct PjrtExecutor(std::sync::Arc<Executable>);

impl Executor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.0.info.batch()
    }

    fn run_f32(&self, input: &[f32]) -> crate::Result<Vec<f32>> {
        self.0.run_f32(input)
    }

    fn compile_ms(&self) -> f64 {
        self.0.compile_ms
    }

    fn stats(&self) -> ExecStats {
        self.0.stats()
    }
}

/// Compile all HLO batch variants of `model` (the paper's bitstream load).
pub fn load_model(manifest: &Manifest, model: &str) -> crate::Result<LoadedModel> {
    let variants: Vec<ArtifactInfo> = manifest.variants(model).into_iter().cloned().collect();
    anyhow::ensure!(!variants.is_empty(), "no HLO artifacts for model '{model}'");
    let tokens_per_image: usize = variants[0].input_shape[1..].iter().product();
    let num_classes = *variants[0].output_shape.last().unwrap();
    let engine = Engine::cpu()?;
    let mut executors: Vec<Box<dyn Executor>> = Vec::new();
    let mut compile_ms = 0.0;
    for v in &variants {
        let e = engine.load(v)?;
        compile_ms += e.compile_ms;
        executors.push(Box::new(PjrtExecutor(e)));
    }
    Ok(LoadedModel { executors, tokens_per_image, num_classes, compile_ms })
}
