//! `hgpipe` — the HG-PIPE leader binary.
//!
//! Subcommands (hand-rolled parser; clap is not vendored offline):
//!   report <id>|all      regenerate a paper table/figure
//!   design               parallelism design for a network
//!   simulate             cycle-accurate pipeline simulation
//!   fifo-search          minimal deadlock-free deep-FIFO depth
//!   serve                serve synthetic requests through the AOT model,
//!                        or real ones over HTTP (--http ADDR)
//!   eval                 accuracy of an AOT model on the eval batch
//!   artifacts            list the AOT artifact manifest

use std::path::PathBuf;

use hgpipe::arch::parallelism::design_network;
use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::faults::FaultPlan;
use hgpipe::coordinator::{ModelServer, Overloaded, Router};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::runtime::kernels::KernelPref;
use hgpipe::runtime::{pipeline, BackendKind, ExecMode, RuntimeConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig};
use hgpipe::util::prng::Prng;
use hgpipe::{report, Result};

struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut argv = std::env::args().skip(1);
        let cmd = argv.next().unwrap_or_else(|| "help".into());
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let rest: Vec<String> = argv.collect();
        let mut i = 0;
        while i < rest.len() {
            if let Some(name) = rest[i].strip_prefix("--") {
                let val = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    i += 1;
                    rest[i].clone()
                } else {
                    "true".into()
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(rest[i].clone());
            }
            i += 1;
        }
        Self { cmd, positional, flags }
    }

    fn flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn network(&self) -> ViTConfig {
        let name = self.flag("network", "deit-tiny");
        ViTConfig::by_name(&name).unwrap_or_else(|| {
            eprintln!("unknown network '{name}' (deit-tiny | deit-small | tiny-synth)");
            std::process::exit(2);
        })
    }

    fn precision(&self) -> Precision {
        let p = self.flag("precision", "a4w3");
        Precision::parse(&p).unwrap_or_else(|| {
            eprintln!("unknown precision '{p}' (a8w8 | a4w4 | a4w3 | a3w3)");
            std::process::exit(2);
        })
    }

    fn artifacts_dir(&self) -> PathBuf {
        if let Some(dir) = self.flags.get("artifacts") {
            return PathBuf::from(dir);
        }
        Manifest::discover().unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    fn backend(&self) -> Result<BackendKind> {
        BackendKind::parse(&self.flag("backend", "interpreter"))
    }

    /// The full runtime configuration: backend, the `--lanes` flag, the
    /// execution mode, the `--replicas` executor count and the
    /// `--kernels` backend preference, all threaded through explicitly.
    /// `--lanes` beats `HGPIPE_LANES`, `--pipeline` beats `HGPIPE_MODE`,
    /// `--replicas` beats `HGPIPE_REPLICAS`, `--kernels` beats
    /// `HGPIPE_KERNELS` — the binary never mutates its own environment
    /// (`set_var` is unsound once threads exist).
    fn runtime_config(&self) -> Result<RuntimeConfig> {
        let lanes = match self.flags.get("lanes") {
            None => None,
            Some(v) => {
                let n: usize = v.parse().map_err(|_| {
                    anyhow::anyhow!("--lanes expects a positive integer, got '{v}'")
                })?;
                anyhow::ensure!(n >= 1, "--lanes must be at least 1");
                Some(n)
            }
        };
        let replicas = match self.flags.get("replicas") {
            None => None,
            Some(v) => {
                let n: usize = v.parse().map_err(|_| {
                    anyhow::anyhow!("--replicas expects a positive integer, got '{v}'")
                })?;
                anyhow::ensure!(n >= 1, "--replicas must be at least 1");
                Some(n)
            }
        };
        let kernels = match self.flags.get("kernels") {
            None => None,
            Some(v) => Some(KernelPref::parse(v)?),
        };
        let queue_cap = match self.flags.get("queue-cap") {
            None => None,
            Some(v) => {
                let n: usize = v.parse().map_err(|_| {
                    anyhow::anyhow!("--queue-cap expects a positive integer, got '{v}'")
                })?;
                anyhow::ensure!(n >= 1, "--queue-cap must be at least 1 (omit it for unbounded)");
                Some(n)
            }
        };
        let faults = match self.flags.get("faults") {
            None => None,
            Some(v) => Some(
                FaultPlan::parse(v)
                    .map_err(|e| anyhow::anyhow!("--faults '{v}' is not a fault spec: {e}"))?,
            ),
        };
        let trace = match self.flags.get("trace") {
            None => None,
            Some(v) => {
                // the parser turns a value-less flag into "true"; a trace
                // needs a real output path, not a file named "true"
                anyhow::ensure!(
                    v != "true",
                    "--trace expects an output path (e.g. --trace trace.jsonl)"
                );
                // RuntimeConfig stays Copy via &'static str; one leak per
                // process invocation is the cost of that
                Some(&*Box::leak(v.clone().into_boxed_str()))
            }
        };
        let backend = self.backend()?;
        let mode = if let Some(v) = self.flags.get("pipeline") {
            // boolean flag: the parser would otherwise swallow a stray
            // token ('--pipeline 4') and silently run auto stages
            anyhow::ensure!(
                v == "true",
                "--pipeline takes no value (got '{v}'); use --stages N for the stage count"
            );
            // the pipeline executor is an interpreter architecture; a
            // non-interpreter backend must reject the flag rather than
            // silently measure the wrong execution mode
            anyhow::ensure!(
                matches!(backend, BackendKind::Interpreter),
                "--pipeline requires the interpreter backend"
            );
            let stages: usize = self.flag("stages", "0").parse().map_err(|_| {
                anyhow::anyhow!(
                    "--stages expects a non-negative integer \
                     (0 = auto: embed stage + one per block)"
                )
            })?;
            let queue_depth: usize = self
                .flag("queue-depth", &pipeline::DEFAULT_QUEUE_DEPTH.to_string())
                .parse()
                .map_err(|_| anyhow::anyhow!("--queue-depth expects a positive integer"))?;
            anyhow::ensure!(queue_depth >= 1, "--queue-depth must be at least 1");
            ExecMode::Pipeline { stages, queue_depth }
        } else {
            // a forgotten `--pipeline` must not silently downgrade a
            // "4-stage pipeline" benchmark to lane-parallel mode
            anyhow::ensure!(
                !self.flags.contains_key("stages") && !self.flags.contains_key("queue-depth"),
                "--stages/--queue-depth only apply with --pipeline"
            );
            ExecMode::Auto
        };
        Ok(RuntimeConfig::new(backend)
            .with_lanes(lanes)
            .with_mode(mode)
            .with_replicas(replicas)
            .with_kernels(kernels)
            .with_queue_capacity(queue_cap)
            .with_faults(faults)
            .with_trace(trace))
    }
}

fn main() {
    let args = Args::parse();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "report" => cmd_report(args),
        "design" => cmd_design(args),
        "simulate" => cmd_simulate(args),
        "fifo-search" => cmd_fifo_search(args),
        "serve" => cmd_serve(args),
        "eval" => cmd_eval(args),
        "artifacts" => cmd_artifacts(args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = "\
hgpipe — HG-PIPE hybrid-grained pipelined ViT acceleration (reproduction)

USAGE: hgpipe <command> [flags]

COMMANDS:
  report <id>|all          regenerate a paper table/figure
                           (fig1 fig2c tab1 fig9a fig9b fig10a-d fig11a-c fig12 tab2)
  design                   parallelism design  [--network N] [--precision P]
  simulate                 cycle-accurate sim  [--network N] [--precision P]
                           [--paradigm hybrid|coarse|fine] [--images N] [--gantt]
  fifo-search              minimal deadlock-free deep-FIFO depth [--network N]
  serve                    serve synthetic requests through the quantized model
                           [--model tiny-synth | --models a,b] [--requests N]
                           [--rate R/s] [--artifacts DIR]
                           [--backend interpreter|pjrt] [--lanes N]
                           [--replicas N] [--kernels scalar|avx2|neon|auto]
                           [--pipeline [--stages N] [--queue-depth N]]
                           [--queue-cap N] [--deadline-ms N] [--faults SPEC]
                           [--trace FILE.jsonl] [--http ADDR]
  eval                     eval-batch accuracy of a quantized model
                           [--model tiny-synth] [--artifacts DIR]
                           [--backend interpreter|pjrt] [--lanes N]
                           [--replicas N] [--kernels scalar|avx2|neon|auto]
                           [--pipeline [--stages N] [--queue-depth N]]
  artifacts                list the artifact manifest [--artifacts DIR]

The default backend is the pure-rust interpreter (runs from the bundle
JSON in the artifacts dir); `--backend pjrt` needs `--features pjrt`.
`--lanes N` sets the interpreter fabric's persistent worker-lane count
for this invocation; unset, the HGPIPE_LANES env var is consulted, then
the machine's available parallelism. `--pipeline` switches the
interpreter to the hybrid-grained spatial executor: the model unrolled
into `--stages` resident stages (0 = auto: a dedicated patch-embed
stage plus one per encoder block, sliced work-proportionally by a GEMM
MAC cost model) connected by bounded queues of `--queue-depth` tiles;
unset, the HGPIPE_MODE env var is consulted (`pipeline` |
`lane-parallel`). `--replicas N` scales a model out to N executor
replicas pulling from one shared queue, each owning its own fabric or
pipeline (env fallback: HGPIPE_REPLICAS). `--models a,b` serves several
models behind one router with per-model and per-replica metrics.
`--kernels` pins the SIMD kernel backend every hot inner loop dispatches
through (selected once at model load; env fallback: HGPIPE_KERNELS;
default auto-detects avx2/neon, falling back to scalar); naming a
backend the host cannot run is an error. Results are bit-identical at
every lane count, stage count, queue depth, replica count and kernel
backend.

Overload & fault flags (serve): `--queue-cap N` bounds the front queue
— at capacity, submits are rejected with a typed Overloaded error and
counted as shed (env fallback: HGPIPE_QUEUE_CAP; unset = unbounded).
`--deadline-ms N` attaches an answer-by deadline to every synthetic
request; a request still queued past its deadline is answered
DeadlineExceeded without computing the forward pass. `--faults SPEC`
enables the deterministic fault-injection harness
(panic:RATE,stall:RATE[:MS],load:RATE,seed:N — env fallback:
HGPIPE_FAULTS): injected replica panics are survived by supervised
restart, requeueing the replica's accepted requests so every accepted
request still gets exactly one reply.

Network front door (serve): `--http ADDR` (e.g. 127.0.0.1:8080; port 0
picks an ephemeral port, printed on stdout) serves real requests over a
dependency-free HTTP/1.1 edge instead of the synthetic loop:
POST /v1/models/<name>/infer (binary little-endian f32 or JSON-array
image body, optional Deadline-Ms header), GET /metrics (Prometheus
text), GET /healthz. Typed overload errors map onto the wire: 429 +
Retry-After on Overloaded, 504 on DeadlineExceeded, 404 on an unknown
model. Env fallback: HGPIPE_HTTP (an explicit --http beats it;
`--http \"\"` disables outright). The process serves until killed.

Observability: `--trace FILE.jsonl` records every request's span tree
(admission, queue wait, dispatch, per-stage residency with stall
intervals, per-op kernel timings) as Chrome-trace JSONL — open the file
in Perfetto (ui.perfetto.dev) or chrome://tracing. Env fallback:
HGPIPE_TRACE (an explicit --trace beats it; `--trace \"\"` disables
outright). Tracing off costs nothing on the hot path and results stay
bit-identical either way. Check a trace with the `trace_check` binary.
";

fn cmd_report(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let ids: Vec<&str> = match args.positional.first().map(|s| s.as_str()) {
        None | Some("all") => report::ALL.to_vec(),
        Some(one) => vec![one],
    };
    for id in ids {
        match report::render(id, &dir) {
            Some(text) => println!("{text}"),
            None => anyhow::bail!("unknown report id '{id}'"),
        }
    }
    Ok(())
}

fn cmd_design(args: &Args) -> Result<()> {
    let cfg = args.network();
    let d = design_network(&cfg, args.precision(), 2);
    println!(
        "network {}  precision {}  target II {}",
        cfg.name,
        d.precision.label(),
        d.target_ii
    );
    println!(
        "{:<22} {:>5} {:>5} {:>5} {:>8} {:>9} {:>6}",
        "module (block 0)", "CIP", "COP", "P", "II", "MOPs", "eta"
    );
    for m in d
        .modules
        .iter()
        .filter(|m| m.spec.name.starts_with("b0.") || !m.spec.name.contains('.'))
    {
        println!(
            "{:<22} {:>5} {:>5} {:>5} {:>8} {:>9.2} {:>6}",
            m.spec.name,
            m.cip,
            m.cop,
            m.p,
            m.ii,
            m.mops(),
            if m.spec.is_mm() { format!("{:.0}%", m.eta * 100.0) } else { "-".into() }
        );
    }
    println!(
        "\ntotal MAC units {}   weight BRAMs {}   accelerator II {}",
        d.total_macs(),
        d.total_brams(),
        d.accelerator_ii()
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = args.network();
    let d = design_network(&cfg, args.precision(), 2);
    let paradigm = match args.flag("paradigm", "hybrid").as_str() {
        "hybrid" => Paradigm::Hybrid,
        "coarse" => Paradigm::CoarseGrained,
        "fine" => Paradigm::FineGrained,
        other => anyhow::bail!("unknown paradigm '{other}'"),
    };
    let images: u64 = args.flag("images", "3").parse()?;
    let sim_cfg = SimConfig::matched(&d, &cfg);
    let p = sim::build_vit(&d, &cfg, paradigm, sim_cfg);
    let t0 = std::time::Instant::now();
    let r = sim::run_fast(&p, images, 2_000_000_000);
    println!(
        "simulated {} stages / {} channels for {} cycles in {:?} ({:.1} Mcycle/s)",
        r.stage_specs.len(),
        r.channel_names.len(),
        r.cycles,
        t0.elapsed(),
        r.cycles as f64 / t0.elapsed().as_secs_f64() / 1e6,
    );
    match &r.stop {
        sim::StopReason::Completed => {
            let s = sim::trace::summarize(&r, 425e6).unwrap();
            println!(
                "stable II {}   first-image {} cycles   latency {:.3} ms   ideal {:.0} img/s",
                s.stable_ii, s.first_image_cycles, s.latency_ms, s.ideal_fps
            );
        }
        sim::StopReason::Deadlock { cycle, waiting } => {
            println!("DEADLOCK at cycle {cycle}; {} stages waiting:", waiting.len());
            for w in waiting.iter().take(8) {
                println!("  {w}");
            }
        }
        sim::StopReason::Budget => println!("cycle budget exhausted"),
    }
    if args.flags.contains_key("gantt") {
        println!("{}", sim::trace::render_gantt(&r, 100));
    }
    Ok(())
}

fn cmd_fifo_search(args: &Args) -> Result<()> {
    let cfg = args.network();
    let d = design_network(&cfg, args.precision(), 2);
    let depth = sim::deadlock::min_deep_fifo_depth(&d, &cfg, 2);
    println!(
        "network {}: minimal deadlock-free deep-FIFO depth = {} groups = {} tokens\n\
         (paper sizes deep FIFOs at 512 tokens — a power-of-two with margin)",
        cfg.name,
        depth,
        depth * 2,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let config = args.runtime_config()?;
    let requests: usize = args.flag("requests", "64").parse()?;
    let rate: f64 = args.flag("rate", "0").parse()?; // 0 = closed loop
    let deadline_ms: u64 = args.flag("deadline-ms", "0").parse()?; // 0 = no deadline
    let deadline = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
    let manifest = Manifest::load(&dir)?;
    // `--models a,b` fronts several per-model servers with one router;
    // `--model` (the default) is the single-model special case of it
    let models: Vec<String> = match args.flags.get("models") {
        Some(list) => {
            // a conflicting --model must error, not be silently ignored
            anyhow::ensure!(
                !args.flags.contains_key("model"),
                "--model conflicts with --models (list every model in --models)"
            );
            let v: Vec<String> =
                list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
            anyhow::ensure!(!v.is_empty(), "--models expects a comma-separated list");
            v
        }
        None => vec![args.flag("model", "tiny-synth")],
    };
    let router = Router::start(&manifest, &models, 2, config)?;
    // the backend every fleet's fabric/pipeline was pinned to at load
    // (resolve_kernels is deterministic, so this matches what the
    // router's executors selected)
    let kern = config.resolve_kernels()?;
    for model in router.models() {
        let s = router.server(&model).expect("router started this model");
        println!(
            "serving '{}' on {} backend x{} executor replica(s), {} kernels \
             ({} token values/img, {} classes, loaded in {:.0} ms)",
            model,
            config.backend.label(),
            s.replicas(),
            kern.name,
            s.tokens_per_image(),
            s.num_classes(),
            s.compile_ms()
        );
        if let Some(a) = s.artifact() {
            println!(
                "  weights: one shared artifact, {:.1} MiB across {} replica(s)",
                a.footprint_bytes() as f64 / (1024.0 * 1024.0),
                s.replicas()
            );
        }
        if let Some(cap) = s.queue_capacity() {
            println!("  admission: bounded front queue, capacity {cap} (overload sheds)");
        }
    }
    if let Some(plan) = config.resolve_faults() {
        println!(
            "fault injection ON (seed {}): panic {:.1}%, stall {:.1}% x{}ms, load-fail {:.1}%",
            plan.seed,
            plan.panic_rate * 100.0,
            plan.stall_rate * 100.0,
            plan.stall_ms,
            plan.load_fail_rate * 100.0
        );
    }
    if let Some(path) = config.resolve_trace() {
        println!("tracing ON -> {path} (Chrome-trace JSONL; open in Perfetto)");
    }

    // `--http ADDR` flips serve from the synthetic traffic loop to the
    // network front door. Flag precedence matches every other knob:
    // explicit --http beats the HGPIPE_HTTP env fallback, and
    // `--http ""` disables an env-configured edge outright.
    let http_addr: Option<String> = match args.flags.get("http") {
        Some(v) => {
            anyhow::ensure!(
                v != "true",
                "--http expects a listen address (e.g. --http 127.0.0.1:8080; \
                 port 0 picks an ephemeral port)"
            );
            if v.is_empty() {
                None
            } else {
                Some(v.clone())
            }
        }
        None => hgpipe::server::addr_from_env(),
    };
    if let Some(addr) = http_addr {
        anyhow::ensure!(
            !args.flags.contains_key("requests") && !args.flags.contains_key("rate"),
            "--requests/--rate drive the synthetic loop and do not apply with --http"
        );
        return serve_http(&addr, router);
    }

    let mut rng = Prng::new(7);
    let mk_image = |rng: &mut Prng, n_tok: usize| -> Vec<f32> {
        (0..n_tok).map(|_| rng.f64() as f32).collect()
    };
    // per-model image sizes, resolved once (submission still routes by
    // name — that is the router path being exercised)
    let n_toks: Vec<usize> = models
        .iter()
        .map(|m| router.server(m).expect("router started this model").tokens_per_image())
        .collect();
    let mut rxs = Vec::with_capacity(requests);
    let t0;
    if rate > 0.0 {
        // open-loop Poisson arrivals: generate each image lazily right
        // before its submit (pre-materializing a long run would hold the
        // whole workload in memory for no benefit)
        t0 = std::time::Instant::now();
        for i in 0..requests {
            let model: &str = &models[i % models.len()];
            let image = mk_image(&mut rng, n_toks[i % models.len()]);
            match router.submit_with_deadline(model, image, deadline) {
                Ok(rx) => rxs.push(rx),
                // open loop under a bounded queue: shed is the expected
                // overload response, reported via metrics, not an abort
                Err(e) if e.downcast_ref::<Overloaded>().is_some() => {}
                Err(e) => return Err(e),
            }
            let gap = rng.exp(1.0 / rate);
            std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        }
    } else {
        // closed loop: pre-generate the round-robin traffic so the
        // throughput timer measures serving, not the PRNG
        let traffic: Vec<(&str, Vec<f32>)> = (0..requests)
            .map(|i| {
                let model: &str = &models[i % models.len()];
                (model, mk_image(&mut rng, n_toks[i % models.len()]))
            })
            .collect();
        t0 = std::time::Instant::now();
        for (model, image) in traffic {
            rxs.push(router.submit_with_deadline(model, image, deadline)?);
        }
    }
    let mut answered = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => answered += 1,
            // an expired deadline is the requested overload behavior,
            // not a serving failure — count it via metrics instead
            Ok(Err(e)) if e.downcast_ref::<hgpipe::coordinator::DeadlineExceeded>().is_some() => {}
            // closed loop propagates failures (as `infer_all` did); the
            // open loop tolerates stragglers and reports via metrics
            Ok(Err(e)) if rate <= 0.0 => return Err(e),
            Err(e) if rate <= 0.0 => anyhow::bail!("reply lost: {e}"),
            _ => {}
        }
    }
    let dt = t0.elapsed();
    if rate <= 0.0 {
        println!(
            "{answered} inferences in {dt:?} = {:.1} img/s",
            answered as f64 / dt.as_secs_f64()
        );
    }
    for line in router.metrics_lines() {
        println!("{line}");
    }
    // grab a handle on the shared sink *before* the router drops (the
    // registry only holds a Weak — letting the last Arc go would let a
    // later open re-create the file), then drop the router so its
    // replica/stage threads exit and flush their rings, and only then
    // close the writer and report
    let tele = router
        .models()
        .first()
        .and_then(|m| router.server(m))
        .map(|s| s.telemetry().clone())
        .unwrap_or_default();
    drop(router);
    if let Some(path) = tele.path().map(str::to_string) {
        tele.finish();
        println!(
            "trace: {} events -> {path} ({} dropped to ring overflow)",
            tele.written(),
            tele.dropped()
        );
    }
    Ok(())
}

/// The `serve --http` mode: real requests over a socket instead of the
/// synthetic loop. Parks forever once bound — the process serves until
/// it is killed (the smoke harness and deployments both stop it with a
/// signal; queued requests on a live drain still get their one reply,
/// see `hgpipe::server`).
fn serve_http(addr: &str, router: Router) -> Result<()> {
    let router = std::sync::Arc::new(router);
    let server =
        hgpipe::server::HttpServer::bind(addr, router, hgpipe::server::HttpConfig::default())?;
    println!(
        "http: listening on http://{} ({} workers; POST /v1/models/<name>/infer, \
         GET /metrics, GET /healthz)",
        server.local_addr(),
        server.live_workers()
    );
    // a parent polling our stdout for the bound port (the ephemeral
    // `--http 127.0.0.1:0` smoke path) must see the line immediately
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = args.artifacts_dir();
    let model = args.flag("model", "tiny-synth");
    let config = args.runtime_config()?;
    let manifest = Manifest::load(&dir)?;
    let (tokens, labels, shape) = load_eval_set(&dir)?;
    let server = ModelServer::start_with_config(&manifest, &model, 1, config)?;
    anyhow::ensure!(
        server.tokens_per_image() == shape[1] * shape[2],
        "eval set shape {:?} does not match model '{}'",
        shape,
        model
    );
    let per = shape[1] * shape[2];
    let images: Vec<Vec<f32>> = tokens.chunks(per).map(|c| c.to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = server.infer_all(images)?;
    let correct =
        responses.iter().zip(&labels).filter(|(r, &l)| r.argmax == l as usize).count();
    println!(
        "eval '{}': {}/{} correct = {:.2}% in {:?} ({:.1} img/s)",
        model,
        correct,
        labels.len(),
        100.0 * correct as f64 / labels.len() as f64,
        t0.elapsed(),
        labels.len() as f64 / t0.elapsed().as_secs_f64(),
    );
    Ok(())
}

/// Load the aot-emitted eval batch (raw little-endian f32 + u8).
fn load_eval_set(dir: &std::path::Path) -> Result<(Vec<f32>, Vec<u8>, [usize; 3])> {
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let v = hgpipe::util::json::Json::parse(&manifest_text)
        .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
    let es = v
        .get("eval_set")
        .ok_or_else(|| anyhow::anyhow!("manifest has no eval_set — re-run `make artifacts`"))?;
    let sh: Vec<usize> = es
        .req("shape")
        .map_err(|e| anyhow::anyhow!(e))?
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as usize)
        .collect();
    let tok_name = es.req("tokens").map_err(|e| anyhow::anyhow!(e))?.as_str().unwrap().to_string();
    let lab_name = es.req("labels").map_err(|e| anyhow::anyhow!(e))?.as_str().unwrap().to_string();
    let tokens_raw = std::fs::read(dir.join(tok_name))?;
    let labels = std::fs::read(dir.join(lab_name))?;
    let tokens: Vec<f32> = tokens_raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    anyhow::ensure!(tokens.len() == sh[0] * sh[1] * sh[2], "eval token size mismatch");
    Ok((tokens, labels, [sh[0], sh[1], sh[2]]))
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.artifacts_dir())?;
    println!(
        "{:<28} {:<12} {:<8} {:<18} {:<12}",
        "artifact (pjrt)", "model", "prec", "input", "output"
    );
    for a in &manifest.artifacts {
        println!(
            "{:<28} {:<12} {:<8} {:<18} {:<12}",
            a.name,
            a.model,
            a.precision,
            format!("{:?}", a.input_shape),
            format!("{:?}", a.output_shape)
        );
    }
    println!(
        "\n{:<28} {:<12} {:<8} {:<18} {:<12}",
        "bundle (interpreter)", "model", "prec", "tokens/img", "batches"
    );
    for b in &manifest.bundles {
        println!(
            "{:<28} {:<12} {:<8} {:<18} {:<12}",
            b.name,
            b.model,
            b.precision,
            format!("{:?}", b.input_shape),
            format!("{:?}", b.batches)
        );
    }
    Ok(())
}
