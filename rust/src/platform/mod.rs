//! Device resource models: the FPGAs the paper deploys on (ZCU102,
//! VCK190) and the V100 GPU comparator, with the datasheet numbers used
//! by the roofline (Fig. 1) and comparison (Table 2) generators.



/// An FPGA platform's resource envelope.
#[derive(Debug, Clone, PartialEq)]
pub struct Fpga {
    pub name: String,
    /// LUT-6 count.
    pub luts: u64,
    /// DSP48/DSP58 slices.
    pub dsps: u64,
    /// BRAM-36k blocks.
    pub brams: u64,
    /// URAM blocks (1 URAM ~ 8 BRAM-36k for capacity accounting, Table 2 fn4).
    pub urams: u64,
    /// Achievable clock for this design family (Hz).
    pub freq_hz: f64,
    /// External memory bandwidth (bytes/s).
    pub dram_bw: f64,
}

impl Fpga {
    /// ZCU102 (Zynq UltraScale+ ZU9EG).
    pub fn zcu102() -> Self {
        Self {
            name: "ZCU102".into(),
            luts: 274_080,
            dsps: 2_520,
            brams: 912,
            urams: 0,
            freq_hz: 375e6, // paper's achieved PL clock on this design
            dram_bw: 19.2e9, // DDR4-2400 x64
        }
    }

    /// VCK190 (Versal VC1902), PL-side resources (no AI Engines used).
    pub fn vck190() -> Self {
        Self {
            name: "VCK190".into(),
            luts: 899_840,
            dsps: 1_968,
            brams: 967,
            urams: 463,
            freq_hz: 425e6,
            dram_bw: 25.6e9, // LPDDR4 x2
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "zcu102" => Some(Self::zcu102()),
            "vck190" => Some(Self::vck190()),
            _ => None,
        }
    }

    /// Effective BRAM-36k capacity including URAM (1 URAM = 8 BRAM).
    pub fn bram_equivalent(&self) -> u64 {
        self.brams + 8 * self.urams
    }

    /// On-chip weight capacity in bits if every BRAM/URAM held weights.
    pub fn onchip_bits(&self) -> u64 {
        self.bram_equivalent() * 36 * 1024
    }

    /// Peak MAC/s when MACs are built from DSPs only (2 low-bit MACs per
    /// DSP48 via the standard packing trick).
    pub fn dsp_peak_macs(&self) -> f64 {
        2.0 * self.dsps as f64 * self.freq_hz
    }

    /// Peak MAC/s when LUTs also build MACs (Sec. 4.4.1), with
    /// `frac` of the LUT budget spent on MAC units of `mac_luts` each.
    pub fn lut_peak_macs(&self, mac_luts: u64, frac: f64) -> f64 {
        (self.luts as f64 * frac / mac_luts as f64) * self.freq_hz
    }
}

/// GPU comparator model (Table 2's V100 baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct Gpu {
    pub name: String,
    pub freq_hz: f64,
    pub fp32_tflops: f64,
    pub dram_bw: f64,
    /// Paper-measured DeiT-tiny throughput (Table 2 col 1).
    pub deit_tiny_fps: f64,
}

impl Gpu {
    pub fn v100() -> Self {
        Self {
            name: "V100".into(),
            freq_hz: 1455e6,
            fp32_tflops: 15.7,
            dram_bw: 900e9,
            deit_tiny_fps: 2529.0,
        }
    }
}

/// BRAM-36k geometry used by the paper's Table 1 efficiency formula:
/// the SDP 512x72 mode (36 kbit = 512 deep x 72 wide).
pub const BRAM_WIDTH: u64 = 72;
pub const BRAM_DEPTH: u64 = 512;
pub const BRAM_BITS: u64 = BRAM_WIDTH * BRAM_DEPTH;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bram_geometry_is_36kbit() {
        assert_eq!(BRAM_BITS, 36 * 1024);
    }

    #[test]
    fn vck190_fits_deit_tiny_weights() {
        // the paper deploys all of DeiT-tiny (5.5M params at 3-4 bits)
        // on a single VCK190 — the capacity model must allow that
        let f = Fpga::vck190();
        let weight_bits = 5_500_000u64 * 4;
        assert!(f.onchip_bits() > weight_bits);
    }

    #[test]
    fn zcu102_cannot_hold_all_weights_at_4bit_with_design_overhead() {
        // paper footnote 3: ZCU102 cannot freeze all layers -> 4-way split.
        // At 100% utilization it would "fit" numerically, but activations,
        // FIFOs, and the 512x72 layout overhead push it over; the paper's
        // measured usage (324.5 BRAM for 1/4 network) confirms.
        let f = Fpga::zcu102();
        let quarter_usage = 324.5f64;
        assert!(4.0 * quarter_usage > f.brams as f64);
    }

    #[test]
    fn dsp_roofline_below_lut_roofline() {
        // Fig 1: the DSP-only roofline (~3.2 TOP/s claim context) is far
        // below what LUT MACs unlock
        let f = Fpga::vck190();
        let dsp = f.dsp_peak_macs();
        let lut = f.lut_peak_macs(11, 0.5);
        assert!(lut > 2.0 * dsp);
    }
}
