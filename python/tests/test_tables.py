"""Tests for the LUT table generators (paper Sec. 4.4)."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import numerics, tables
from compile.quantize import QuantParams

OUT4 = QuantParams(scale=0.125, zero_point=0, bits=4, signed=True)
OUT8U = QuantParams(scale=1.0 / 255, zero_point=0, bits=8, signed=False)


class TestBuildTable:
    def test_requant_is_monotone(self):
        t = tables.requant_table("rq", -1000, 1000, 0.01, OUT4)
        ent = np.asarray(t.entries)
        assert (np.diff(ent) >= 0).all()
        assert t.depth == 64

    def test_identity_tracks_function(self):
        t = tables.requant_table("rq", -1000, 1000, 0.01, OUT4)
        xs = np.arange(-1000, 1001, 7)
        approx = t.lookup_real(xs)
        exact = np.clip(xs * 0.01, OUT4.qmin * 0.125, OUT4.qmax * 0.125)
        # max error: half an input bucket * slope + half output LSB
        bucket = (1 << t.shift) * 0.01
        assert np.abs(approx - exact).max() <= bucket / 2 + 0.125

    def test_lookup_matches_index_arithmetic(self):
        t = tables.requant_table("rq", -500, 500, 0.02, OUT4)
        xs = np.array([-500, -499, 0, 499, 500, -10**6, 10**6])
        idx = t.index_of(xs)
        assert (idx >= 0).all() and (idx < 64).all()
        assert idx[0] == 0
        assert idx[-1] == 63  # clamp above
        assert idx[-2] == 0  # clamp below

    @given(st.integers(-(2**20), 2**20), st.integers(64, 2**20))
    @settings(max_examples=100)
    def test_no_index_overflow_property(self, alpha, span):
        t = tables.requant_table("rq", alpha, alpha + span, 0.01, OUT4)
        xs = np.array([alpha, alpha + span, alpha + span // 2])
        assert (t.index_of(xs) < t.depth).all()


class TestGeluFusion:
    def test_fused_curve_shape(self):
        # gelu(x) ~ 0 for x<<0, ~x for x>>0 — the fused table must show both
        t = tables.gelu_requant_table("g", -800, 800, 0.0078125, OUT4)
        lo = t.lookup_real(np.array([-800]))[0]
        hi = t.lookup_real(np.array([790]))[0]
        assert abs(lo) <= 0.125  # saturated near zero
        assert hi > 0.5

    def test_fused_vs_compose(self):
        # fused table == quantize(gelu(dequant(x))) within one bucket error
        t = tables.gelu_requant_table("g", -800, 800, 0.0078125, OUT4)
        xs = np.arange(-800, 801, 13)
        fused = t.lookup(xs)
        exact = np.clip(
            np.round(np.vectorize(numerics.gelu)(xs * 0.0078125) / 0.125),
            OUT4.qmin,
            OUT4.qmax,
        )
        assert np.abs(fused - exact).max() <= 1  # one output LSB


class TestInvertedExp:
    def test_beta_anchor_is_exact(self):
        # exp(0) = 1 must map to the top entry (the softmax max element)
        t = tables.exp_table_inverted("e", -5000, 0, 0.001)
        v = t.lookup_real(np.array([0]))[0]
        assert abs(v - 1.0) < 2.0 / 255

    def test_normal_exp_misses_anchor(self):
        # the non-inverted table anchors alpha: the value at x=0 lands in the
        # top bucket whose midpoint underestimates exp(0) (the Fig 11b bug)
        tn = tables.exp_table_normal("e", -5000, 0, 0.001)
        ti = tables.exp_table_inverted("e", -5000, 0, 0.001)
        err_n = abs(tn.lookup_real(np.array([0]))[0] - 1.0)
        err_i = abs(ti.lookup_real(np.array([0]))[0] - 1.0)
        assert err_i <= err_n

    def test_monotone_decreasing_in_x(self):
        t = tables.exp_table_inverted("e", -3000, 0, 0.002)
        xs = np.arange(-3000, 1, 50)
        vals = t.lookup_real(xs)
        assert (np.diff(vals) >= 0).all()  # increasing toward x=0


class TestJointCalibration:
    def test_removes_saturated_entries(self):
        # huge range + hard clamp -> many repeated end entries pre-calibration
        raw = tables.requant_table("r", -100000, 100000, 0.001, OUT4)
        ent = np.asarray(raw.entries)
        sat_raw = (ent == ent[0]).sum() + (ent == ent[-1]).sum()
        cal = tables.joint_calibrate("r", lambda x: x, -100000, 100000, 0.001, 6, OUT4)
        ent_c = np.asarray(cal.entries)
        sat_cal = (ent_c == ent_c[0]).sum() + (ent_c == ent_c[-1]).sum()
        assert sat_cal < sat_raw

    def test_idempotent_at_fixed_point(self):
        # re-running calibration from a calibrated range changes nothing
        t1 = tables.joint_calibrate("r", lambda x: x, -500, 500, 0.001, 6, OUT4)
        beta1 = t1.alpha + ((t1.depth) << t1.shift) - 1
        t2 = tables.joint_calibrate("r", lambda x: x, t1.alpha, beta1, 0.001, 6, OUT4)
        assert abs(t2.alpha - t1.alpha) <= (1 << t1.shift)
        assert t2.shift <= t1.shift

    def test_shrunk_range_clamps_consistently(self):
        # values outside the calibrated range clamp to the end entries,
        # which for a monotone fn equal the uncalibrated saturated values
        cal = tables.joint_calibrate("r", lambda x: x, -100000, 100000, 0.001, 6, OUT4)
        xs = np.array([-100000, 100000])
        vals = cal.lookup(xs)
        assert vals[0] == cal.entries[0] and vals[1] == cal.entries[-1]

    def test_calibrated_reduces_mse(self):
        xs = np.arange(-3000, 3000, 7)
        raw = tables.requant_table("r", -100000, 100000, 0.001, OUT4)
        cal = tables.joint_calibrate("r", lambda x: x, -100000, 100000, 0.001, 6, OUT4)
        f = lambda x: max(min(x, OUT4.qmax * 0.125), OUT4.qmin * 0.125)
        mse_raw = tables.mse_of_table(raw, xs, f, 0.001)
        mse_cal = tables.mse_of_table(cal, xs, f, 0.001)
        assert mse_cal <= mse_raw


class TestSegmentedRecip:
    def test_paper_mse_improvement(self):
        # Fig 10d: segmentation reduces MSE by ~10x on a high-dynamic-range
        # reciprocal (paper: 0.032 -> 0.0034 on their distribution)
        alpha, beta, in_scale = 200, 40000, 1.0 / 255
        rng = np.random.default_rng(0)
        # softmax-sum-like distribution: mass concentrated at the low end
        xs = np.clip((rng.lognormal(7.0, 1.0, 20000)).astype(np.int64), alpha, beta)
        seg = tables.recip_table_segmented("r", alpha, beta, in_scale)
        flat = tables.recip_table_flat("r", alpha, beta, in_scale)
        f = lambda x: 1.0 / x
        mse_seg = tables.mse_of_table(seg, xs, f, in_scale)
        mse_flat = tables.mse_of_table(flat, xs, f, in_scale)
        assert mse_seg < mse_flat
        assert mse_flat / max(mse_seg, 1e-12) > 3.0  # qualitative 'much better'

    def test_pivot_at_first_eighth(self):
        seg = tables.recip_table_segmented("r", 1000, 9000, 0.01)
        assert seg.pivot == 1000 + (8000 >> 3)

    def test_segments_cover_range_continuously(self):
        seg = tables.recip_table_segmented("r", 100, 10000, 0.01)
        xs = np.arange(100, 10001, 3)
        vals = seg.lookup_real(xs)
        exact = 1.0 / (xs * 0.01)
        rel = np.abs(vals - exact) / exact
        assert np.median(rel) < 0.2

    def test_scale_relation_is_pot(self):
        seg = tables.recip_table_segmented("r", 200, 40000, 1.0 / 255)
        ratio = seg.steep.out_scale / seg.flat.out_scale
        assert ratio >= 1.0
        assert abs(math.log2(ratio) - round(math.log2(ratio))) < 1e-12


class TestRsqrt:
    def test_tracks_function(self):
        t = tables.rsqrt_table("rs", 50, 100000, 0.0625)
        xs = np.arange(50, 100001, 97)
        vals = t.lookup_real(xs)
        exact = 1.0 / np.sqrt(xs * 0.0625)
        # steep near alpha: compare medians rather than worst case
        rel = np.abs(vals - exact) / exact
        assert np.median(rel) < 0.15

    def test_entries_fit_bits(self):
        t = tables.rsqrt_table("rs", 50, 100000, 0.0625)
        ent = np.asarray(t.entries)
        assert (ent >= 0).all() and (ent < (1 << 12)).all()


class TestSerialization:
    def test_roundtrip(self, tmp_path):
        t = tables.requant_table("rq", -100, 100, 0.5, OUT4)
        s = tables.recip_table_segmented("rc", 10, 1000, 0.01)
        p = tmp_path / "t.json"
        tables.dump_tables({"rq": t, "rc": s}, str(p))
        loaded = tables.load_tables(str(p))
        assert loaded["rq"] == t
        assert loaded["rc"].steep == s.steep and loaded["rc"].pivot == s.pivot
