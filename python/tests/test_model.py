"""Tests for the L2 quantized model: calibration, folding, exactness."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = M.tiny_synth()
    rng = np.random.default_rng(0)
    params = M.init_params(rng, cfg)
    toks = M.patchify(rng.uniform(0, 1, (4, 32, 32, 3)), cfg)
    qm = M.build_quantized(params, cfg, toks)
    return cfg, params, toks, qm


class TestConfig:
    def test_deit_tiny_matches_paper(self):
        cfg = M.deit_tiny()
        assert cfg.tokens == 196
        assert cfg.dim == 192
        assert cfg.head_dim == 64
        assert cfg.hidden == 768
        # paper Table 2: 2.5 GOPs/inf, 5.5M params
        assert 2.3e9 < cfg.ops_per_inference < 2.7e9

    def test_deit_small_matches_paper(self):
        cfg = M.deit_small()
        assert cfg.dim == 384 and cfg.heads == 6
        # paper: 9.2 GOPs
        assert 8.5e9 < cfg.ops_per_inference < 10.0e9

    def test_patchify_roundtrip_shape(self):
        cfg = M.tiny_synth()
        imgs = np.arange(2 * 32 * 32 * 3, dtype=np.float64).reshape(2, 32, 32, 3)
        toks = M.patchify(imgs, cfg)
        assert toks.shape == (2, cfg.tokens, cfg.patch_dim)
        # first patch top-left pixel == image top-left pixel
        assert toks[0, 0, 0] == imgs[0, 0, 0, 0]


class TestBuildQuantized:
    def test_lut_inventory(self, tiny_setup):
        cfg, _, _, qm = tiny_setup
        # per block: 2 rsqrt + 2 ln_rq + qkv + exp + recip(2) + prob + rv +
        # proj + gelu + mm2 = 13; plus pe + ln_f(2) = 3
        assert qm.lut_count() == cfg.depth * 13 + 3

    def test_residual_quantizer_shared_scale(self, tiny_setup):
        _, _, _, qm = tiny_setup
        for i in range(qm.cfg.depth):
            assert qm.act_params[f"b{i}.res"].scale == qm.s0

    def test_guard_shift_prevents_overflow(self, tiny_setup):
        # mirror the model's own per-block residual-span bound and assert
        # the int32-safety invariant (cmax>>g)^2 * CI < 2^31
        cfg, _, _, qm = tiny_setup
        rq = qm.act_params["b0.res"].qmax
        for i in range(cfg.depth):
            span1 = (2 * i + 1) * rq if i > 0 else qm.act_params["pe_out"].qmax
            span2 = (2 * i + 2) * rq
            for ln, span in (("ln1", span1), ("ln2", span2)):
                g = qm.scalars[f"b{i}.{ln}.guard"]
                cmax = 2 * span * cfg.dim
                assert ((cmax >> g) ** 2) * cfg.dim < 2**31

    def test_exp_tables_are_inverted(self, tiny_setup):
        _, _, _, qm = tiny_setup
        for i in range(qm.cfg.depth):
            assert qm.luts[f"b{i}.attn.exp"].inverted

    def test_weights_fit_bits(self, tiny_setup):
        cfg, _, _, qm = tiny_setup
        lim = 1 << (cfg.weight_bits - 1)
        for name, w in qm.weights.items():
            if name.endswith("_w") and name != "head_w":
                assert np.abs(np.asarray(w)).max() < lim, name


class TestIntForward:
    def test_np_equals_jnp_exactly(self, tiny_setup):
        _, _, toks, qm = tiny_setup
        xq = qm.input_q.quantize(toks)
        ln = M.forward_int_np(qm, xq)
        lj = np.asarray(M.forward_int_jnp(qm, jnp.asarray(xq)))
        np.testing.assert_allclose(ln, lj, atol=1e-4)

    def test_logits_correlate_with_float(self, tiny_setup):
        cfg, params, toks, qm = tiny_setup
        lf = M.forward_f32(params, toks, cfg)
        li = M.forward_int_np(qm, qm.input_q.quantize(toks))
        corr = np.corrcoef(lf.ravel(), li.ravel())[0, 1]
        assert corr > 0.6, f"int/float correlation too low: {corr}"

    def test_end_to_end_jnp_includes_input_quant(self, tiny_setup):
        _, _, toks, qm = tiny_setup
        l1 = np.asarray(M.end_to_end_jnp(qm, jnp.asarray(toks, jnp.float32)))
        xq = qm.input_q.quantize(toks)
        l2 = np.asarray(M.forward_int_jnp(qm, jnp.asarray(xq)))
        np.testing.assert_allclose(l1, l2, atol=1e-4)

    def test_batch_independence(self, tiny_setup):
        # each image's logits must not depend on its batch neighbours
        _, _, toks, qm = tiny_setup
        xq = qm.input_q.quantize(toks)
        full = M.forward_int_np(qm, xq)
        single = M.forward_int_np(qm, xq[:1])
        np.testing.assert_allclose(full[:1], single, atol=1e-9)

    def test_deterministic(self, tiny_setup):
        _, _, toks, qm = tiny_setup
        xq = qm.input_q.quantize(toks)
        a = M.forward_int_np(qm, xq)
        b = M.forward_int_np(qm, xq)
        np.testing.assert_array_equal(a, b)


class TestAblationOptions:
    def test_normal_exp_table_when_disabled(self):
        cfg = M.tiny_synth()
        rng = np.random.default_rng(1)
        params = M.init_params(rng, cfg)
        toks = M.patchify(rng.uniform(0, 1, (2, 32, 32, 3)), cfg)
        qm = M.build_quantized(params, cfg, toks, opts=M.LutOptions(inverted_exp=False))
        assert not qm.luts["b0.attn.exp"].inverted

    def test_flat_recip_when_disabled(self):
        from compile import tables

        cfg = M.tiny_synth()
        rng = np.random.default_rng(1)
        params = M.init_params(rng, cfg)
        toks = M.patchify(rng.uniform(0, 1, (2, 32, 32, 3)), cfg)
        qm = M.build_quantized(params, cfg, toks, opts=M.LutOptions(segmented_recip=False))
        assert isinstance(qm.luts["b0.attn.recip"], tables.LutTable)


class TestPallasBlockParity:
    def test_block0_pallas_equals_ref_dataflow(self, tiny_setup):
        """The block-level pallas artifact function must match the ref
        dataflow bit-for-bit on the residual-stream input."""
        from compile.aot import block_pallas_fn

        cfg, _, toks, qm = tiny_setup
        fn, spec = block_pallas_fn(qm, 0)
        rng = np.random.default_rng(5)
        x = rng.integers(-7, 8, (cfg.tokens, cfg.dim)).astype(np.int32)
        got = np.asarray(fn(jnp.asarray(x))[0])

        # reference: same ops through the LutExec numpy strategy
        strat = M.LutExec(qm, np)
        sc, W = qm.scalars, qm.weights
        n = strat.layernorm("b0.ln1", x, sc["b0.ln1.guard"], None)
        qkv = np.rint(
            n.astype(np.float64) @ W["b0.qkv_w"].astype(np.float64)
        ).astype(np.int64) + W["b0.qkv_b"]
        qkv = strat.requant("b0.qkv", qkv, None, None)
        h, dh = cfg.heads, cfg.head_dim
        heads = []
        for hi in range(h):
            q = qkv[:, hi * dh : (hi + 1) * dh]
            k = qkv[:, cfg.dim + hi * dh : cfg.dim + (hi + 1) * dh]
            v = qkv[:, 2 * cfg.dim + hi * dh : 2 * cfg.dim + (hi + 1) * dh]
            scores = q.astype(np.int64) @ k.T.astype(np.int64)
            probs = strat.softmax("b0.attn", scores, None, None)
            heads.append(probs.astype(np.int64) @ v.astype(np.int64))
        a = np.concatenate(heads, axis=-1)
        a = strat.requant("b0.rv", a, None, None)
        o = a.astype(np.int64) @ W["b0.proj_w"].astype(np.int64) + W["b0.proj_b"]
        o = strat.requant("b0.proj", o, None, None)
        x2 = x + o
        n2 = strat.layernorm("b0.ln2", x2, sc["b0.ln2.guard"], None)
        hd = n2.astype(np.int64) @ W["b0.mm1_w"].astype(np.int64) + W["b0.mm1_b"]
        hd = strat.gelu("b0.gelu", hd, None, None)
        o2 = hd.astype(np.int64) @ W["b0.mm2_w"].astype(np.int64) + W["b0.mm2_b"]
        o2 = strat.requant("b0.mm2", o2, None, None)
        want = x2 + o2
        np.testing.assert_array_equal(got, want.astype(np.int32))
