"""Unit tests for the deterministic scalar numerics (mirrored in rust)."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import numerics


class TestRoundHalfAway:
    @pytest.mark.parametrize(
        "x,want",
        [(0.5, 1.0), (-0.5, -1.0), (1.5, 2.0), (-1.5, -2.0), (2.4, 2.0), (-2.4, -2.0), (0.0, 0.0)],
    )
    def test_cases(self, x, want):
        assert numerics.round_half_away(x) == want

    @given(st.floats(-1e9, 1e9))
    def test_matches_numpy_half_away(self, x):
        want = math.floor(x + 0.5) if x >= 0 else math.ceil(x - 0.5)
        assert numerics.round_half_away(x) == want


class TestErf:
    def test_endpoints(self):
        assert abs(numerics.erf_approx(0.0)) < 1e-8
        assert abs(numerics.erf_approx(3.0) - 0.99997791) < 1e-5
        assert numerics.erf_approx(-2.0) == -numerics.erf_approx(2.0)

    @given(st.floats(-5, 5))
    @settings(max_examples=200)
    def test_against_math_erf(self, x):
        assert abs(numerics.erf_approx(x) - math.erf(x)) < 1.6e-7

    def test_gelu_known_values(self):
        assert abs(numerics.gelu(0.0)) < 1e-12
        assert abs(numerics.gelu(1.0) - 0.8413447) < 1e-5
        assert abs(numerics.gelu(-1.0) - (-0.1586553)) < 1e-5
        # GeLU(x) -> x for large x, -> 0 for very negative x
        assert abs(numerics.gelu(10.0) - 10.0) < 1e-6
        assert abs(numerics.gelu(-10.0)) < 1e-6


class TestPotShift:
    def test_exact_fit(self):
        # span 63 over 64 entries -> shift 0
        assert numerics.pot_shift(0, 63, 6) == 0
        # span 64 needs shift 1
        assert numerics.pot_shift(0, 64, 6) == 1
        assert numerics.pot_shift(0, 127, 6) == 1
        assert numerics.pot_shift(0, 128, 6) == 2

    def test_ceiling_never_overflows(self):
        # paper: ceiling (not rounding) so the max datum never overflows
        for beta in [63, 64, 100, 1000, 12345, 10**9]:
            s = numerics.pot_shift(0, beta, 6)
            assert (beta - 0) >> s <= 63

    @given(st.integers(-(2**30), 2**30), st.integers(1, 2**30), st.integers(2, 12))
    @settings(max_examples=300)
    def test_property_minimal_and_safe(self, alpha, span, n):
        beta = alpha + span
        s = numerics.pot_shift(alpha, beta, n)
        limit = (1 << n) - 1
        assert (beta - alpha) >> s <= limit  # safe
        if s > 0:  # minimal
            assert (beta - alpha) >> (s - 1) > limit

    def test_degenerate_span(self):
        assert numerics.pot_shift(5, 5, 6) == 0
        assert numerics.pot_shift(5, 4, 6) == 0


class TestPotIndex:
    @given(st.integers(-(2**30), 2**30), st.integers(1, 2**20), st.integers(2, 10),
           st.integers(-(2**31), 2**31 - 1))
    @settings(max_examples=300)
    def test_index_in_range(self, alpha, span, n, x):
        beta = alpha + span
        s = numerics.pot_shift(alpha, beta, n)
        idx = numerics.pot_index(x, alpha, s, n)
        assert 0 <= idx <= (1 << n) - 1

    def test_inverted_anchors_beta(self):
        # x == beta must land on index 0 (the sensitive anchor, Sec 4.4.7)
        s = numerics.pot_shift(-5000, 0, 6)
        assert numerics.pot_index_inverted(0, 0, s, 6) == 0
        assert numerics.pot_index_inverted(-1 << s, 0, s, 6) == 1

    def test_normal_anchors_alpha(self):
        s = numerics.pot_shift(-5000, 0, 6)
        assert numerics.pot_index(-5000, -5000, s, 6) == 0


class TestMidpoints:
    def test_midpoint_bucket0(self):
        # bucket 0 with shift 2 covers [alpha, alpha+3]
        assert numerics.index_midpoint(100, 0, 2) == 101.5

    def test_inverted_rep_is_anchor_side(self):
        # bucket 0 of an inverted table represents exactly beta (the anchor)
        assert numerics.index_midpoint_inverted(0, 0, 2) == 0.0
        assert numerics.index_midpoint_inverted(0, 1, 2) == -4.0

    @given(st.integers(-1000, 1000), st.integers(0, 63), st.integers(0, 10))
    def test_midpoint_inside_bucket(self, alpha, i, s):
        m = numerics.index_midpoint(alpha, i, s)
        assert alpha + (i << s) <= m <= alpha + ((i + 1) << s) - 1 + 0.5


class TestQuantizeEntry:
    def test_clamps(self):
        assert numerics.quantize_entry(100.0, 1.0, 0, -8, 7) == 7
        assert numerics.quantize_entry(-100.0, 1.0, 0, -8, 7) == -8

    def test_rounds_half_away(self):
        assert numerics.quantize_entry(0.5, 1.0, 0, -8, 7) == 1
        assert numerics.quantize_entry(-0.5, 1.0, 0, -8, 7) == -1

    @given(st.floats(-100, 100), st.sampled_from([0.25, 0.5, 1.0, 2.0]))
    def test_in_bounds(self, y, scale):
        q = numerics.quantize_entry(y, scale, 0, -8, 7)
        assert -8 <= q <= 7
