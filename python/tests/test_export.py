"""Tests for the interpreter-bundle exporter (compile/export.py)."""

import json
import struct

import numpy as np
import pytest

from compile import export, model as M


@pytest.fixture(scope="module")
def qm():
    # untrained fixed-seed model: bundle structure and bit-exactness of the
    # emission pipeline do not depend on trained weights
    qm, _ = export.golden_model(train_steps=0)
    return qm


def test_bundle_has_full_weight_and_lut_set(qm):
    d = export.bundle_dict(qm)
    cfg = qm.cfg
    assert d["format"] == export.BUNDLE_FORMAT
    assert d["model"] == "tiny-synth"
    w = d["weights"]
    assert len(w["pe_w"]) == cfg.patch_dim * cfg.dim
    assert len(w["head_w"]) == cfg.dim * cfg.num_classes
    for i in range(cfg.depth):
        assert len(w[f"b{i}.qkv_w"]) == cfg.dim * 3 * cfg.dim
        assert len(w[f"b{i}.mm1_w"]) == cfg.dim * cfg.hidden
        assert len(w[f"b{i}.mm2_b"]) == cfg.dim
        for lut in ("ln1.rsqrt", "ln1.rq", "qkv", "attn.exp", "attn.recip",
                    "attn.prob", "rv", "proj", "ln2.rsqrt", "ln2.rq", "gelu", "mm2"):
            assert f"b{i}.{lut}" in d["luts"], f"b{i}.{lut}"
    assert "pe" in d["luts"] and "ln_f.rsqrt" in d["luts"] and "ln_f.rq" in d["luts"]
    assert len(d["head"]["bias"]) == cfg.num_classes
    assert {"ln_f", "b0.ln1", "b0.ln2"} <= set(d["guards"])


def test_bundle_floats_survive_json_roundtrip(qm):
    d = export.bundle_dict(qm)
    back = json.loads(json.dumps(d))
    assert back["input"]["scale"] == d["input"]["scale"]
    assert back["head"]["logit_scale"] == d["head"]["logit_scale"]
    assert back["head"]["bias"] == d["head"]["bias"]


def test_emit_golden_is_self_consistent(qm, tmp_path):
    """The emitted logits must equal a fresh forward over the emitted
    f32 tokens — the exact contract the rust interpreter test relies on."""
    m = export.emit_golden(str(tmp_path), qm, eval_n=4)
    cfg = qm.cfg
    per = cfg.tokens * cfg.patch_dim
    raw = (tmp_path / "golden_tokens.bin").read_bytes()
    toks = np.array(struct.unpack(f"<{4 * per}f", raw), dtype=np.float64)
    toks = toks.reshape(4, cfg.tokens, cfg.patch_dim)
    x_q = qm.input_q.quantize(toks)
    logits = np.asarray(M.forward_int_np(qm, x_q), dtype="<f8")
    assert (tmp_path / "golden_logits.bin").read_bytes() == logits.tobytes()
    entry = m["bundles"]["tinyvit_bundle"]
    assert entry["batches"] == export.BUNDLE_BATCHES
    assert entry["input"] == [cfg.tokens, cfg.patch_dim]
    assert m["eval_set"]["shape"] == [4, cfg.tokens, cfg.patch_dim]
