"""AOT lowering tests: HLO text round-trips and the golden fixture."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tables
from compile.aot import block_pallas_fn, golden_fixture, lower_to_file, to_hlo_text


@pytest.fixture(scope="module")
def tiny_qm():
    cfg = M.tiny_synth()
    rng = np.random.default_rng(0)
    params = M.init_params(rng, cfg)
    toks = M.patchify(rng.uniform(0, 1, (2, 32, 32, 3)), cfg)
    return cfg, M.build_quantized(params, cfg, toks), toks


class TestHloText:
    def test_simple_fn_lowers_to_hlo_text(self):
        def fn(x):
            return (jnp.matmul(x, x) + 1.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ROOT" in text

    def test_model_lowering_has_int_ops(self, tiny_qm):
        cfg, qm, _ = tiny_qm
        lowered = jax.jit(lambda x: (M.end_to_end_jnp(qm, x),)).lower(
            jax.ShapeDtypeStruct((2, cfg.tokens, cfg.patch_dim), jnp.float32)
        )
        text = to_hlo_text(lowered)
        assert "s32" in text  # integer dataflow survived lowering
        assert "f32" in text  # dequantized logits

    def test_lower_to_file(self, tiny_qm, tmp_path):
        cfg, qm, _ = tiny_qm
        p = tmp_path / "m.hlo.txt"
        info = lower_to_file(
            lambda x: (M.end_to_end_jnp(qm, x),),
            [jax.ShapeDtypeStruct((1, cfg.tokens, cfg.patch_dim), jnp.float32)],
            str(p),
        )
        assert p.exists() and info["bytes"] > 1000

    def test_block_pallas_lowers(self, tiny_qm):
        cfg, qm, _ = tiny_qm
        fn, spec = block_pallas_fn(qm, 0)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        assert "HloModule" in text


class TestGoldenFixture:
    def test_fixture_is_deterministic(self):
        a = golden_fixture()
        b = golden_fixture()
        assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)

    def test_fixture_covers_all_table_kinds(self):
        fx = golden_fixture()
        assert set(fx) == {
            "requant",
            "requant_calibrated",
            "gelu",
            "exp_inverted",
            "recip_segmented",
            "rsqrt",
        }

    def test_fixture_tables_reload(self):
        fx = golden_fixture()
        t = tables.LutTable.from_dict(fx["requant"]["table"])
        assert t.depth == 64
        s = tables.SegmentedTable.from_dict(fx["recip_segmented"]["table"])
        assert s.pivot > 0

    def test_in_scales_are_exact_binary(self):
        # cross-language determinism requires exactly-representable scales
        fx = golden_fixture()
        for case in fx.values():
            sc = case["spec"]["in_scale"]
            # must be a power of two times a small integer
            m, e = np.frexp(sc)
            assert m in (0.5, 0.75), f"in_scale {sc} not a simple binary fraction"


class TestArtifactsOnDisk:
    """Validate whatever `make artifacts` produced (skip when absent)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    def _need(self, name):
        p = os.path.join(self.ART, name)
        if not os.path.exists(p):
            pytest.skip(f"{name} not built yet (run `make artifacts`)")
        return p

    def test_manifest_lists_existing_files(self):
        p = self._need("manifest.json")
        with open(p) as f:
            manifest = json.load(f)
        for name, info in manifest["artifacts"].items():
            assert os.path.exists(os.path.join(self.ART, info["path"])), name

    def test_golden_tables_json(self):
        p = self._need("golden_tables.json")
        with open(p) as f:
            fx = json.load(f)
        fresh = golden_fixture()
        assert json.dumps(fx, sort_keys=True) == json.dumps(fresh, sort_keys=True)

    def test_accuracy_ladder_shape(self):
        p = self._need("accuracy_ladder.json")
        with open(p) as f:
            acc = json.load(f)
        for prec in ("a4w4", "a3w3"):
            ladder = acc[prec]["ladder"]
            assert ladder["fp32"] >= ladder["+segmented_recip"] - 0.02
            # the full pipeline must beat the uncalibrated PoT baseline
            assert ladder["+segmented_recip"] >= ladder["pot_lut"] - 0.05
