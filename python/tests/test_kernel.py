"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Integers admit no tolerance: every comparison is exact equality.
Hypothesis sweeps shapes, tilings, value ranges, and table geometries.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import tables
from compile.kernels import (
    attention_head,
    layernorm_tiled,
    lut_apply_tiled,
    matmul_os,
    ref,
    seg_apply_tiled,
)
from compile.quantize import QuantParams

OUT4 = QuantParams(scale=0.125, zero_point=0, bits=4, signed=True)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# matmul_os
# ---------------------------------------------------------------------------


class TestMatmulOS:
    @pytest.mark.parametrize(
        "t,ci,co,tp,cip,cop",
        [
            (196, 192, 64, 2, 6, 4),  # QKV-Gen-like (Table 1 row)
            (196, 64, 196, 2, 4, 28),  # QK-MatMul-like
            (196, 192, 768, 2, 12, 24),  # MatMul1-like
            (4, 8, 8, 1, 8, 8),  # degenerate single tile
            (8, 16, 16, 8, 16, 16),  # whole-tensor tiles
        ],
    )
    def test_table1_shapes_exact(self, t, ci, co, tp, cip, cop):
        r = _rng(t + ci + co)
        x = r.integers(-7, 8, (t, ci)).astype(np.int32)
        w = r.integers(-7, 8, (ci, co)).astype(np.int32)
        b = r.integers(-1000, 1000, co).astype(np.int32)
        got = matmul_os(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), tp=tp, cip=cip, cop=cop)
        want = ref.matmul_acc(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(
        ti=st.integers(1, 6),
        cii=st.integers(1, 4),
        coi=st.integers(1, 4),
        tp=st.sampled_from([1, 2, 4]),
        cip=st.sampled_from([1, 2, 8]),
        cop=st.sampled_from([1, 4]),
        amax=st.sampled_from([1, 7, 127]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_shape_sweep(self, ti, cii, coi, tp, cip, cop, amax, seed):
        t, ci, co = ti * tp, cii * cip, coi * cop
        r = _rng(seed)
        x = r.integers(-amax, amax + 1, (t, ci)).astype(np.int32)
        w = r.integers(-amax, amax + 1, (ci, co)).astype(np.int32)
        got = matmul_os(jnp.asarray(x), jnp.asarray(w), tp=tp, cip=cip, cop=cop)
        want = ref.matmul_acc(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bias_default_zero(self):
        x = jnp.ones((4, 4), jnp.int32)
        w = jnp.ones((4, 4), jnp.int32)
        got = matmul_os(x, w, tp=2, cip=2, cop=2)
        np.testing.assert_array_equal(np.asarray(got), np.full((4, 4), 4, np.int32))

    def test_rejects_nondividing_tiles(self):
        x = jnp.ones((5, 4), jnp.int32)
        w = jnp.ones((4, 4), jnp.int32)
        with pytest.raises(AssertionError):
            matmul_os(x, w, tp=2, cip=2, cop=2)


# ---------------------------------------------------------------------------
# lut_ops
# ---------------------------------------------------------------------------


def _mk_lut(alpha, beta, in_scale=0.01, bits=6, inverted=False):
    if inverted:
        t = tables.exp_table_inverted("e", alpha, beta, in_scale, n_bits=bits)
    else:
        t = tables.requant_table("r", alpha, beta, in_scale, OUT4, n_bits=bits)
    return ref.lut_params(t)


class TestLutApply:
    @given(
        tt=st.integers(1, 8),
        c=st.integers(1, 64),
        tp=st.sampled_from([1, 2]),
        alpha=st.integers(-10000, 0),
        span=st.integers(64, 100000),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_vs_ref(self, tt, c, tp, alpha, span, seed):
        t = tt * tp
        lut = _mk_lut(alpha, alpha + span)
        r = _rng(seed)
        x = r.integers(alpha - span, alpha + 2 * span, (t, c)).astype(np.int32)
        got = lut_apply_tiled(jnp.asarray(x), lut, tp=tp)
        want = ref.lut_apply(jnp.asarray(x), lut)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_inverted_lut(self):
        lut = _mk_lut(-5000, 0, in_scale=0.001, inverted=True)
        x = _rng(3).integers(-6000, 1, (8, 16)).astype(np.int32)
        got = lut_apply_tiled(jnp.asarray(x), lut, tp=2)
        want = ref.lut_apply(jnp.asarray(x), lut)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_out_of_range_clamps(self):
        lut = _mk_lut(0, 630)
        x = np.array([[-(2**31), 2**31 - 1]], np.int32)
        got = np.asarray(lut_apply_tiled(jnp.asarray(x), lut, tp=1))
        ent = np.asarray(lut[4])
        assert got[0, 0] == ent[0] and got[0, 1] == ent[-1]


class TestSegApply:
    @given(
        tt=st.integers(1, 8),
        c=st.integers(1, 8),
        alpha=st.integers(1, 500),
        span=st.integers(128, 100000),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=30, deadline=None)
    def test_hypothesis_vs_ref(self, tt, c, alpha, span, seed):
        seg_t = tables.recip_table_segmented("r", alpha, alpha + span, 1.0 / 255)
        seg = ref.seg_params(seg_t)
        r = _rng(seed)
        x = r.integers(max(alpha, 1), alpha + span, (tt * 2, c)).astype(np.int32)
        got = seg_apply_tiled(jnp.asarray(x), seg, tp=2)
        want = ref.seg_apply(jnp.asarray(x), seg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pivot_boundary(self):
        seg_t = tables.recip_table_segmented("r", 100, 10000, 0.01)
        seg = ref.seg_params(seg_t)
        x = np.array([[seg_t.pivot - 1, seg_t.pivot, seg_t.pivot + 1]], np.int32)
        got = seg_apply_tiled(jnp.asarray(x), seg, tp=1)
        want = ref.seg_apply(jnp.asarray(x), seg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


def _ln_tables(ci, guard, amax=16):
    vmax = ((2 * ci * amax) >> guard) ** 2 * ci
    rs = tables.rsqrt_table("rs", 1, max(vmax, 2), (2.0 ** (2 * guard)) / ci)
    pmax = 2 * ci * amax * 4096
    rq = tables.requant_table("rq", -pmax, pmax, rs.out_scale, OUT4)
    return ref.lut_params(rs), ref.lut_params(rq)


class TestLayerNorm:
    @given(
        tt=st.integers(1, 6),
        ci=st.sampled_from([16, 64, 192]),
        amax=st.sampled_from([3, 7, 15]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_vs_ref(self, tt, ci, amax, seed):
        guard = 0 if ci * amax * 2 < 46341 // ci else 2
        rs, rq = _ln_tables(ci, guard, amax)
        r = _rng(seed)
        x = r.integers(-amax, amax + 1, (tt * 2, ci)).astype(np.int32)
        got = layernorm_tiled(jnp.asarray(x), guard, rs, rq, tp=2)
        want = ref.layernorm_int(jnp.asarray(x), guard, rs, rq)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_constant_token_is_centered(self):
        # a constant token has zero variance -> c == 0 -> output constant
        rs, rq = _ln_tables(16, 0)
        x = np.full((2, 16), 5, np.int32)
        got = np.asarray(layernorm_tiled(jnp.asarray(x), 0, rs, rq, tp=2))
        assert (got == got[0, 0]).all()


# ---------------------------------------------------------------------------
# fused attention head
# ---------------------------------------------------------------------------


def _attn_tables(t, dh, amax=7):
    import math

    smax = amax * amax * dh
    in_scale = 1.0 / max(smax, 1)
    exp_t = tables.exp_table_inverted("e", -2 * smax, 0, in_scale)
    recip_t = tables.recip_table_segmented("rc", 1, t * 255, 1.0 / 255)
    r_fine = recip_t.flat.out_scale
    # er integer value corresponding to prob == 1.0 bounds the table range
    er_scale = (1.0 / 255) * r_fine
    prob_out = QuantParams(scale=1.0 / 15, zero_point=0, bits=4, signed=False)
    prob_t = tables.requant_table("p", 0, int(1.0 / er_scale) + 1, er_scale, prob_out)
    return ref.lut_params(exp_t), ref.seg_params(recip_t), ref.lut_params(prob_t)


class TestAttentionHead:
    @given(
        tt=st.sampled_from([4, 8, 16]),
        dh=st.sampled_from([8, 32, 64]),
        amax=st.sampled_from([3, 7]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_vs_ref(self, tt, dh, amax, seed):
        e, s, p = _attn_tables(tt, dh, amax)
        r = _rng(seed)
        q = r.integers(-amax, amax + 1, (tt, dh)).astype(np.int32)
        k = r.integers(-amax, amax + 1, (tt, dh)).astype(np.int32)
        v = r.integers(-amax, amax + 1, (tt, dh)).astype(np.int32)
        got = attention_head(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), e, s, p, tp=2)
        want = ref.attention_head_int(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), e, s, p)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_uniform_scores_give_uniform_probs(self):
        # all-equal q/k -> equal scores -> softmax uniform -> RV = mean-ish
        e, s, p = _attn_tables(8, 8)
        q = np.ones((8, 8), np.int32)
        k = np.ones((8, 8), np.int32)
        v = _rng(1).integers(-7, 8, (8, 8)).astype(np.int32)
        got = np.asarray(attention_head(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), e, s, p, tp=2))
        # every output token identical (identical attention rows)
        assert (got == got[0]).all()

    def test_softmax_keeps_peaky_argmax(self):
        # rows with one dominant score: the integer softmax must keep the
        # winner (flat rows legitimately tie under 4-bit prob quantization)
        e, s, p = _attn_tables(8, 8)
        r = _rng(2)
        scores = r.integers(-100, 100, (8, 8)).astype(np.int32)
        winners = r.integers(0, 8, 8)
        scores[np.arange(8), winners] += 300  # ~0.77 in real units: decisive
        got = np.asarray(ref.softmax_int(jnp.asarray(scores), e, s, p))
        assert (got.argmax(-1) == winners).all()

    def test_softmax_flat_rows_are_uniform(self):
        e, s, p = _attn_tables(8, 8)
        scores = np.zeros((4, 8), np.int32)
        got = np.asarray(ref.softmax_int(jnp.asarray(scores), e, s, p))
        assert (got == got[0, 0]).all()
        # ~1/8 at scale 1/15 -> quantized value 2
        assert got[0, 0] in (1, 2)
