"""Deterministic scalar numerics shared by the table generators.

Every function here is mirrored *verbatim* in ``rust/src/lut/numerics.rs``.
The python and rust table generators must agree bit-for-bit (checked by the
golden cross-check test), so:

  * all math is f64,
  * ``erf`` is our own fixed-constant rational approximation (rust has no
    libm ``erf`` in std, and we refuse to depend on platform libm parity),
  * rounding is explicit round-half-away-from-zero (``rne`` differences
    between numpy and rust ``f64::round`` would break the mirror).
"""

from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# scalar helpers
# ---------------------------------------------------------------------------


def round_half_away(x: float) -> float:
    """Round half away from zero — matches rust ``f64::round``."""
    return math.floor(x + 0.5) if x >= 0.0 else math.ceil(x - 0.5)


def clamp(x: int, lo: int, hi: int) -> int:
    return lo if x < lo else hi if x > hi else x


def erf_approx(x: float) -> float:
    """Abramowitz & Stegun 7.1.26 (max abs err 1.5e-7), fixed constants.

    Identical constant set in rust — the only transcendental used by the
    GeLU table generator.
    """
    sign = 1.0 if x >= 0.0 else -1.0
    ax = abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    y = 1.0 - (
        ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
        + 0.254829592
    ) * t * math.exp(-ax * ax)
    return sign * y


def gelu(x: float) -> float:
    """GeLU via erf (paper Eq. 1)."""
    return 0.5 * x * (1.0 + erf_approx(x / math.sqrt(2.0)))


# ---------------------------------------------------------------------------
# Power-of-Two index approximation (paper Sec. 4.4.2, Eq. 5/6/7)
# ---------------------------------------------------------------------------


def pot_shift(alpha: int, beta: int, n_bits: int) -> int:
    """``s_PoT = ceil(log2((beta - alpha) / (2^n - 1)))``, clamped to >= 0.

    Ceiling (not rounding) so the highest datum never overflows the table
    (paper: "We apply a ceiling instead of rounding to avoid index
    overflowing"). Computed purely on integers to avoid log2 precision
    traps: smallest s with ((beta - alpha) >> s) <= 2^n - 1.
    """
    span = beta - alpha
    if span <= 0:
        return 0
    limit = (1 << n_bits) - 1
    s = 0
    while (span >> s) > limit:
        s += 1
    return s


def pot_index(x: int, alpha: int, s: int, n_bits: int) -> int:
    """Eq. 6: ``index = (x - alpha) >> s``, clamped into the table."""
    return clamp((x - alpha) >> s, 0, (1 << n_bits) - 1)


def pot_index_inverted(x: int, beta: int, s: int, n_bits: int) -> int:
    """Eq. 7 (inverted exp table): ``index = (beta - x) >> s``.

    Anchors the zero point at beta so the softmax-sensitive values near
    x == max (i.e. x - max == 0) land on exact table entries.
    """
    return clamp((beta - x) >> s, 0, (1 << n_bits) - 1)


def index_midpoint(alpha: int, i: int, s: int) -> float:
    """Representative (dequant-domain-free) input value of table bucket i.

    Bucket i covers integer inputs [alpha + (i<<s), alpha + ((i+1)<<s) - 1];
    we sample the arithmetic midpoint, matching what the HLS tables did.
    """
    lo = alpha + (i << s)
    hi = alpha + ((i + 1) << s) - 1
    return 0.5 * (lo + hi)


def index_midpoint_inverted(beta: int, i: int, s: int) -> float:
    """Representative input for bucket i of an inverted-index table.

    Inverted tables exist to keep the *anchor* (x == beta, i.e. the softmax
    max element, Sec. 4.4.7) exact, so each bucket samples its anchor-side
    endpoint rather than the midpoint: bucket 0 represents exactly beta.
    """
    return float(beta - (i << s))


# ---------------------------------------------------------------------------
# output quantization of table entries
# ---------------------------------------------------------------------------


def quantize_entry(y: float, scale: float, zero_point: int, qmin: int, qmax: int) -> int:
    """Quantize a real table output to an integer entry."""
    q = int(round_half_away(y / scale)) + zero_point
    return clamp(q, qmin, qmax)
