"""LUT table generation — paper Sec. 4.4, mirrored in ``rust/src/lut/``.

The accelerator implements every non-linear operator (GeLU, Exp, Recip,
Rsqrt, ReQuant) as a small table indexed by a Power-of-Two-shifted integer
(Eq. 6/7). All tables here operate on *integer* inputs (MM accumulators or
integer intermediates) whose real value is ``x_int * in_scale`` — the affine
zero-point corrections are folded into biases upstream, exactly as the HLS
design does.

Table kinds:
  * ``build_table``        — generic PoT-indexed table (Sec. 4.4.2/4.4.4)
  * ``gelu_requant_table`` — GeLU-ReQuant operator fusion (Sec. 4.4.3)
  * ``joint_calibrate``    — Joint Table Range Calibration (Sec. 4.4.5)
  * ``SegmentedTable``     — segmented high-dynamic-range Recip (Sec. 4.4.6)
  * inverted indexing      — Inversed Exponential Table (Sec. 4.4.7, Eq. 7)

The rust generator (``rust/src/lut/``) re-implements these byte-for-byte;
``tests/test_golden_tables.py`` + ``rust tests/golden_tables.rs`` pin both
to the same JSON fixtures.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Callable

import numpy as np

from . import numerics
from .quantize import QuantParams

# Default table geometry (paper Fig. 11c).
EXP_BITS = 6  # 64 entries
EXP_OUT_BITS = 8
GELU_BITS = 6
RECIP_BITS = 6  # x2 segments
RECIP_OUT_BITS = 8
RSQRT_BITS = 6
RSQRT_OUT_BITS = 12
REQUANT_BITS = 6


@dataclass(frozen=True)
class LutTable:
    """A PoT-indexed lookup table.

    real_out = (entries[index] - out_zp) * out_scale, with
    index = (x - alpha) >> shift          (normal)
    index = (alpha - x) >> shift          (inverted; alpha stores beta)
    """

    name: str
    alpha: int
    shift: int
    n_bits: int
    inverted: bool
    out_scale: float
    out_zp: int
    entries: tuple  # tuple[int, ...] so the dataclass stays hashable

    @property
    def depth(self) -> int:
        return 1 << self.n_bits

    def index_of(self, x: np.ndarray) -> np.ndarray:
        x = x.astype(np.int64)
        if self.inverted:
            raw = (self.alpha - x) >> self.shift
        else:
            raw = (x - self.alpha) >> self.shift
        return np.clip(raw, 0, self.depth - 1)

    def lookup(self, x: np.ndarray) -> np.ndarray:
        """Integer-in integer-out table application."""
        ent = np.asarray(self.entries, dtype=np.int32)
        return ent[self.index_of(x)]

    def lookup_real(self, x: np.ndarray) -> np.ndarray:
        return (self.lookup(x).astype(np.float64) - self.out_zp) * self.out_scale

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["entries"] = list(self.entries)
        return d

    @staticmethod
    def from_dict(d: dict) -> "LutTable":
        d = dict(d)
        d["entries"] = tuple(int(e) for e in d["entries"])
        return LutTable(**d)


def pot_out_scale(max_abs: float, bits: int, signed: bool = False) -> float:
    """Power-of-Two output scale so max_abs maps inside the entry range."""
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    if max_abs <= 0.0:
        return 1.0
    # smallest power of two scale with max_abs/scale <= qmax
    k = math.ceil(math.log2(max_abs / qmax))
    return 2.0**k


def build_table(
    name: str,
    fn: Callable[[float], float],
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int,
    out: QuantParams,
    inverted: bool = False,
) -> LutTable:
    """Sample ``fn`` (a real-valued function of the dequantized input) into a
    PoT-indexed table over integer input range [alpha, beta]."""
    shift = numerics.pot_shift(alpha, beta, n_bits)
    depth = 1 << n_bits
    entries = []
    for i in range(depth):
        if inverted:
            mid = numerics.index_midpoint_inverted(beta, i, shift)
        else:
            mid = numerics.index_midpoint(alpha, i, shift)
        y = fn(mid * in_scale)
        entries.append(
            numerics.quantize_entry(y, out.scale, out.zero_point, out.qmin, out.qmax)
        )
    return LutTable(
        name=name,
        alpha=beta if inverted else alpha,
        shift=shift,
        n_bits=n_bits,
        inverted=inverted,
        out_scale=out.scale,
        out_zp=out.zero_point,
        entries=tuple(entries),
    )


# ---------------------------------------------------------------------------
# Sec. 4.4.4 — ReQuant as a table
# ---------------------------------------------------------------------------


def requant_table(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    out: QuantParams,
    n_bits: int = REQUANT_BITS,
) -> LutTable:
    return build_table(name, lambda x: x, alpha, beta, in_scale, n_bits, out)


# ---------------------------------------------------------------------------
# Sec. 4.4.3 — GeLU-ReQuant fusion
# ---------------------------------------------------------------------------


def gelu_requant_table(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    out: QuantParams,
    n_bits: int = GELU_BITS,
) -> LutTable:
    return build_table(name, numerics.gelu, alpha, beta, in_scale, n_bits, out)


# ---------------------------------------------------------------------------
# Sec. 4.4.7 — Inversed Exponential table
# ---------------------------------------------------------------------------


def exp_table_inverted(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int = EXP_BITS,
    out_bits: int = EXP_OUT_BITS,
) -> LutTable:
    """exp(x) for x <= 0 (softmax post-max-subtract), beta anchored at 0."""
    out = QuantParams(
        scale=1.0 / ((1 << out_bits) - 1), zero_point=0, bits=out_bits, signed=False
    )
    return build_table(name, math.exp, alpha, beta, in_scale, n_bits, out, inverted=True)


def exp_table_normal(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int = EXP_BITS,
    out_bits: int = EXP_OUT_BITS,
) -> LutTable:
    """The *non*-inverted exp table — the ablation baseline of Fig. 11b."""
    out = QuantParams(
        scale=1.0 / ((1 << out_bits) - 1), zero_point=0, bits=out_bits, signed=False
    )
    return build_table(name, math.exp, alpha, beta, in_scale, n_bits, out)


# ---------------------------------------------------------------------------
# Sec. 4.4.5 — Joint Table Range Calibration
# ---------------------------------------------------------------------------


def joint_calibrate(
    name: str,
    fn: Callable[[float], float],
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int,
    out: QuantParams,
    max_iters: int = 16,
) -> LutTable:
    """Shrink [alpha, beta] until the clamp-saturated (repeated) entries at
    both ends vanish: find the Least/Most Significant Index and recompute
    the range, iterating to a fixed point (paper Fig. 10c)."""
    for _ in range(max_iters):
        table = build_table(name, fn, alpha, beta, in_scale, n_bits, out)
        ent = table.entries
        depth = len(ent)
        # LSI: last index of the saturated run at the low end.
        lsi = 0
        while lsi + 1 < depth and ent[lsi + 1] == ent[0]:
            lsi += 1
        # MSI: first index of the saturated run at the high end.
        msi = depth - 1
        while msi - 1 > 0 and ent[msi - 1] == ent[depth - 1]:
            msi -= 1
        if lsi == 0 and msi == depth - 1:
            return table
        new_alpha = alpha + (lsi << table.shift)
        new_beta = alpha + ((msi + 1) << table.shift) - 1
        if new_alpha >= new_beta or (new_alpha == alpha and new_beta == beta):
            return table
        alpha, beta = new_alpha, new_beta
    return build_table(name, fn, alpha, beta, in_scale, n_bits, out)


# ---------------------------------------------------------------------------
# Sec. 4.4.6 — Segmented Recip for high dynamic range
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SegmentedTable:
    """Two PoT tables over [alpha, pivot) and [pivot, beta].

    The pivot is the first 1/8 of the span (paper: "empirically divide the
    input range at the first 1/8 for the steep part"). Each segment owns an
    independent (PoT) output scale, so the steep part near zero keeps
    precision.
    """

    name: str
    pivot: int
    steep: LutTable
    flat: LutTable

    def lookup_real(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        steep_v = self.steep.lookup_real(x)
        flat_v = self.flat.lookup_real(x)
        return np.where(x < self.pivot, steep_v, flat_v)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "pivot": self.pivot,
            "steep": self.steep.to_dict(),
            "flat": self.flat.to_dict(),
        }

    @staticmethod
    def from_dict(d: dict) -> "SegmentedTable":
        return SegmentedTable(
            name=d["name"],
            pivot=int(d["pivot"]),
            steep=LutTable.from_dict(d["steep"]),
            flat=LutTable.from_dict(d["flat"]),
        )


def recip_table_segmented(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int = RECIP_BITS,
    out_bits: int = RECIP_OUT_BITS,
) -> SegmentedTable:
    alpha = max(alpha, 1)  # reciprocal of a non-positive sum never occurs
    span = beta - alpha
    pivot = alpha + max(span >> 3, 1)
    # Independent PoT output scales per segment.
    steep_max = 1.0 / (alpha * in_scale)
    flat_max = 1.0 / (pivot * in_scale)
    steep_out = QuantParams(
        scale=pot_out_scale(steep_max, out_bits), zero_point=0, bits=out_bits, signed=False
    )
    flat_out = QuantParams(
        scale=pot_out_scale(flat_max, out_bits), zero_point=0, bits=out_bits, signed=False
    )
    steep = build_table(
        name + ".steep", lambda x: 1.0 / x, alpha, pivot - 1, in_scale, n_bits, steep_out
    )
    flat = build_table(
        name + ".flat", lambda x: 1.0 / x, pivot, beta, in_scale, n_bits, flat_out
    )
    return SegmentedTable(name=name, pivot=pivot, steep=steep, flat=flat)


def recip_table_flat(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int = RECIP_BITS + 1,
    out_bits: int = RECIP_OUT_BITS,
) -> LutTable:
    """Unsegmented Recip baseline (same total depth: 128 entries) — the
    ablation comparator for Fig. 10d / Fig. 11b."""
    alpha = max(alpha, 1)
    out = QuantParams(
        scale=pot_out_scale(1.0 / (alpha * in_scale), out_bits),
        zero_point=0,
        bits=out_bits,
        signed=False,
    )
    return build_table(name, lambda x: 1.0 / x, alpha, beta, in_scale, n_bits, out)


# ---------------------------------------------------------------------------
# Rsqrt (LayerNorm) table
# ---------------------------------------------------------------------------


def rsqrt_table(
    name: str,
    alpha: int,
    beta: int,
    in_scale: float,
    n_bits: int = RSQRT_BITS,
    out_bits: int = RSQRT_OUT_BITS,
) -> LutTable:
    alpha = max(alpha, 1)
    out = QuantParams(
        scale=pot_out_scale(1.0 / math.sqrt(alpha * in_scale), out_bits),
        zero_point=0,
        bits=out_bits,
        signed=False,
    )
    return build_table(
        name, lambda x: 1.0 / math.sqrt(x) if x > 0 else 0.0, alpha, beta, in_scale, n_bits, out
    )


# ---------------------------------------------------------------------------
# serialization of a full table set (shared with rust via JSON)
# ---------------------------------------------------------------------------


def dump_tables(tables: dict, path: str) -> None:
    payload = {}
    for k, v in tables.items():
        kind = "segmented" if isinstance(v, SegmentedTable) else "lut"
        payload[k] = {"kind": kind, "data": v.to_dict()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def load_tables(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    out = {}
    for k, v in payload.items():
        if v["kind"] == "segmented":
            out[k] = SegmentedTable.from_dict(v["data"])
        else:
            out[k] = LutTable.from_dict(v["data"])
    return out


def mse_of_table(table, xs: np.ndarray, fn: Callable[[float], float], in_scale: float) -> float:
    """MSE of the table against the real function over integer samples xs."""
    approx = (
        table.lookup_real(xs) if isinstance(table, SegmentedTable) else table.lookup_real(xs)
    )
    exact = np.array([fn(float(x) * in_scale) for x in xs])
    return float(np.mean((approx - exact) ** 2))
