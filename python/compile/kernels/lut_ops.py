"""Pallas LUT-application kernels — the non-linear PEs (Sec. 4.4).

Each non-linear module of the accelerator (GeLU, ReQuant, Exp, Recip,
Rsqrt) is a bank of parallel table-lookup units: compute the PoT-shifted
index (a subtract + arithmetic shift — no DSP), then read the table. The
Pallas kernel is the same shape: an elementwise tile op whose body is
shift → clip → gather. The table rides along as a kernel operand (the
BRAM/LUTRAM analogue) broadcast to every grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_kernel(x_ref, ent_ref, o_ref, *, alpha: int, shift: int, n_bits: int, inverted: bool):
    x = x_ref[...].astype(jnp.int32)
    raw = jnp.right_shift(alpha - x if inverted else x - alpha, shift)
    idx = jnp.clip(raw, 0, (1 << n_bits) - 1)
    o_ref[...] = jnp.take(ent_ref[...], idx)


def lut_apply_tiled(
    x: jnp.ndarray,
    lut,
    *,
    tp: int = 2,
) -> jnp.ndarray:
    """Apply a LUT tuple (ref.lut_params layout) over a (T, C) int32 tensor,
    tiled token-wise with parallelism TP (Table 1: LayerNorm/Softmax P=2)."""
    alpha, shift, n_bits, inverted, entries = lut
    t, c = x.shape
    assert t % tp == 0, f"TP must divide T: {t} % {tp}"
    depth = int(entries.shape[0])
    return pl.pallas_call(
        functools.partial(
            _lut_kernel, alpha=alpha, shift=shift, n_bits=n_bits, inverted=inverted
        ),
        grid=(t // tp,),
        in_specs=[
            pl.BlockSpec((tp, c), lambda ti: (ti, 0)),
            pl.BlockSpec((depth,), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((tp, c), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), entries)


def _seg_kernel(
    x_ref,
    s_ent_ref,
    f_ent_ref,
    o_ref,
    *,
    pivot: int,
    s_alpha: int,
    s_shift: int,
    s_bits: int,
    f_alpha: int,
    f_shift: int,
    f_bits: int,
    ratio_log2: int,
):
    x = x_ref[...].astype(jnp.int32)
    si = jnp.clip(jnp.right_shift(x - s_alpha, s_shift), 0, (1 << s_bits) - 1)
    fi = jnp.clip(jnp.right_shift(x - f_alpha, f_shift), 0, (1 << f_bits) - 1)
    sv = jnp.left_shift(jnp.take(s_ent_ref[...], si), ratio_log2)
    fv = jnp.take(f_ent_ref[...], fi)
    o_ref[...] = jnp.where(x < pivot, sv, fv)


def seg_apply_tiled(x: jnp.ndarray, seg, *, tp: int = 2) -> jnp.ndarray:
    """Segmented-table lookup (Recip, Sec. 4.4.6) over (T, C) int32."""
    pivot, steep, flat, ratio_log2 = seg
    s_alpha, s_shift, s_bits, s_inv, s_ent = steep
    f_alpha, f_shift, f_bits, f_inv, f_ent = flat
    assert not s_inv and not f_inv, "recip segments are normal-indexed"
    t, c = x.shape
    assert t % tp == 0
    return pl.pallas_call(
        functools.partial(
            _seg_kernel,
            pivot=pivot,
            s_alpha=s_alpha,
            s_shift=s_shift,
            s_bits=s_bits,
            f_alpha=f_alpha,
            f_shift=f_shift,
            f_bits=f_bits,
            ratio_log2=ratio_log2,
        ),
        grid=(t // tp,),
        in_specs=[
            pl.BlockSpec((tp, c), lambda ti: (ti, 0)),
            pl.BlockSpec((int(s_ent.shape[0]),), lambda ti: (0,)),
            pl.BlockSpec((int(f_ent.shape[0]),), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((tp, c), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), s_ent, f_ent)
