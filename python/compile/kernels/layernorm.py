"""Pallas integer LayerNorm — the three-pass LayerNorm module (Table 1).

The accelerator's LayerNorm makes three passes over each token (hence its
II is 3x the elementwise II in Table 1): sum, variance, normalize. This
kernel processes TP tokens per grid step and performs all three passes in
registers (the passes are over the *channel* axis, which fits on-chip —
exactly why the module needs no coarse-grained buffering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(
    x_ref,
    rs_ent_ref,
    rq_ent_ref,
    o_ref,
    *,
    guard_shift: int,
    rs_alpha: int,
    rs_shift: int,
    rs_bits: int,
    rq_alpha: int,
    rq_shift: int,
    rq_bits: int,
):
    x = x_ref[...].astype(jnp.int32)
    ci = x.shape[-1]
    # pass 1: mean (kept as sum; c = CI*x - S keeps everything integer)
    s = jnp.sum(x, axis=-1, keepdims=True)
    c = ci * x - s
    # pass 2: variance accumulator with overflow guard shift
    cg = jnp.right_shift(c, guard_shift)
    v = jnp.sum(cg * cg, axis=-1, keepdims=True)
    ri = jnp.clip(jnp.right_shift(v - rs_alpha, rs_shift), 0, (1 << rs_bits) - 1)
    r = jnp.take(rs_ent_ref[...], ri)
    # pass 3: normalize + ReQuant LUT
    p = c * r
    qi = jnp.clip(jnp.right_shift(p - rq_alpha, rq_shift), 0, (1 << rq_bits) - 1)
    o_ref[...] = jnp.take(rq_ent_ref[...], qi)


def layernorm_tiled(
    x: jnp.ndarray,
    guard_shift: int,
    rsqrt_lut,
    requant_lut,
    *,
    tp: int = 2,
) -> jnp.ndarray:
    """Integer LayerNorm over (T, CI) int32; exact match of ref.layernorm_int."""
    rs_alpha, rs_shift, rs_bits, rs_inv, rs_ent = rsqrt_lut
    rq_alpha, rq_shift, rq_bits, rq_inv, rq_ent = requant_lut
    assert not rs_inv and not rq_inv
    t, ci = x.shape
    assert t % tp == 0
    return pl.pallas_call(
        functools.partial(
            _ln_kernel,
            guard_shift=guard_shift,
            rs_alpha=rs_alpha,
            rs_shift=rs_shift,
            rs_bits=rs_bits,
            rq_alpha=rq_alpha,
            rq_shift=rq_shift,
            rq_bits=rq_bits,
        ),
        grid=(t // tp,),
        in_specs=[
            pl.BlockSpec((tp, ci), lambda ti: (ti, 0)),
            pl.BlockSpec((int(rs_ent.shape[0]),), lambda ti: (0,)),
            pl.BlockSpec((int(rq_ent.shape[0]),), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((tp, ci), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, ci), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), rs_ent, rq_ent)
