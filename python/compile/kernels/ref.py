"""Pure-jnp oracle of the HG-PIPE integer dataflow.

These functions define the *canonical* integer semantics of every module in
the accelerator (Table 1 of the paper): StMM/DyMM accumulation, LUT-based
non-linear operators, integer LayerNorm and Softmax. The Pallas kernels in
this package implement the same functions tile-by-tile and the test suite
asserts **exact integer equality** against these references — integers admit
no tolerance.

All activations are int32 carrying low-bit values; accumulators are int32.
A LUT is passed as the tuple ``(alpha, shift, n_bits, inverted, entries)``
with ``entries`` an int32 array — the jit-traceable mirror of
``tables.LutTable``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lut_params(table):
    """tables.LutTable -> jit-friendly tuple."""
    return (
        int(table.alpha),
        int(table.shift),
        int(table.n_bits),
        bool(table.inverted),
        jnp.asarray(np.asarray(table.entries, dtype=np.int32)),
    )


def seg_params(seg):
    """tables.SegmentedTable -> (pivot, steep_tuple, flat_tuple, ratio_log2).

    The two segments own independent PoT output scales. 1/x is decreasing,
    so the steep segment's outputs (and hence its PoT scale) dominate:
    steep_scale >= flat_scale. Downstream integer arithmetic uses the
    *flat* (finer) scale as the common one, left-shifting steep entries by
    ratio_log2 = log2(steep_scale / flat_scale) >= 0 at lookup time.
    """
    import math

    ratio = seg.steep.out_scale / seg.flat.out_scale
    ratio_log2 = int(round(math.log2(ratio))) if ratio > 0 else 0
    assert ratio_log2 >= 0, "steep segment must have the coarser scale"
    assert abs(ratio - 2.0**ratio_log2) < 1e-12, "segment scales must be PoT-related"
    return (int(seg.pivot), lut_params(seg.steep), lut_params(seg.flat), ratio_log2)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


def matmul_acc(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """StMM/DyMM accumulation: int32 OS matmul. x:(T,CI) w:(CI,CO) -> (T,CO)."""
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    return acc


def residual_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Residual Add module: same-scale integer add (one extra bit of range)."""
    return a.astype(jnp.int32) + b.astype(jnp.int32)


# ---------------------------------------------------------------------------
# LUT application (Sec. 4.4.2 / 4.4.7)
# ---------------------------------------------------------------------------


def lut_apply(x: jnp.ndarray, lut) -> jnp.ndarray:
    alpha, shift, n_bits, inverted, entries = lut
    x = x.astype(jnp.int32)
    if inverted:
        raw = jnp.right_shift(alpha - x, shift)
    else:
        raw = jnp.right_shift(x - alpha, shift)
    idx = jnp.clip(raw, 0, (1 << n_bits) - 1)
    return jnp.take(entries, idx)


def seg_apply(x: jnp.ndarray, seg) -> jnp.ndarray:
    """Segmented table lookup, result in the flat segment's (finer) scale."""
    pivot, steep, flat, ratio_log2 = seg
    sv = jnp.left_shift(lut_apply(x, steep), ratio_log2)
    fv = lut_apply(x, flat)
    return jnp.where(x.astype(jnp.int32) < pivot, sv, fv)


# ---------------------------------------------------------------------------
# LayerNorm module (three passes; Rsqrt table; Table 1 row "LayerNorm")
# ---------------------------------------------------------------------------


def layernorm_int(x: jnp.ndarray, guard_shift: int, rsqrt_lut, requant_lut) -> jnp.ndarray:
    """Integer LayerNorm.

    x: (T, CI) int32. Per token:
      pass 1: S = sum(x)            -> centered c = CI*x - S  (scale s/CI)
      pass 2: V = sum((c>>g)^2)     -> r = RsqrtLUT(V)
      pass 3: p = c * r             -> ReQuantLUT(p)
    Affine LN weights (gamma/beta) are folded into the following MM's
    weights/bias, as on the accelerator.
    """
    x = x.astype(jnp.int32)
    ci = x.shape[-1]
    s = jnp.sum(x, axis=-1, keepdims=True)
    c = ci * x - s
    cg = jnp.right_shift(c, guard_shift)
    v = jnp.sum(cg * cg, axis=-1, keepdims=True)
    r = lut_apply(v, rsqrt_lut)
    p = c * r
    return lut_apply(p, requant_lut)


# ---------------------------------------------------------------------------
# Softmax module (max-subtract, inverted Exp LUT, segmented Recip LUT)
# ---------------------------------------------------------------------------


def softmax_int(scores: jnp.ndarray, exp_lut, recip_seg, prob_lut) -> jnp.ndarray:
    """Integer softmax over the last axis.

    scores: (..., T) int32 accumulators of QK^T.
      pass 1: m = max(scores)
      pass 2: e = ExpLUT(scores - m)   (inverted index, beta anchored at 0)
      pass 3: E = sum(e); r = RecipLUT(E); prob = ReQuantLUT(e * r)
    """
    scores = scores.astype(jnp.int32)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = lut_apply(scores - m, exp_lut)
    tot = jnp.sum(e, axis=-1, keepdims=True)
    r = seg_apply(tot, recip_seg)
    return lut_apply(e * r, prob_lut)


# ---------------------------------------------------------------------------
# GeLU (fused GeLU-ReQuant table, Sec. 4.4.3) — just a lut_apply
# ---------------------------------------------------------------------------


def gelu_int(acc: jnp.ndarray, gelu_lut) -> jnp.ndarray:
    return lut_apply(acc, gelu_lut)


# ---------------------------------------------------------------------------
# one attention head (DyMM chain): scores -> softmax -> probs @ V
# ---------------------------------------------------------------------------


def attention_head_int(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    exp_lut,
    recip_seg,
    prob_lut,
) -> jnp.ndarray:
    """q,k,v: (T, dh) int32 -> (T, dh) int32 accumulator of R@V.

    QK MatMul and RV MatMul are DyMMs: the K / V operands stream from the
    deep buffers (Sec. 4.2); numerically they are plain int matmuls.
    """
    scores = matmul_acc(q, k.T)
    probs = softmax_int(scores, exp_lut, recip_seg, prob_lut)
    return matmul_acc(probs, v)


# ---------------------------------------------------------------------------
# float references for accuracy experiments
# ---------------------------------------------------------------------------


def layernorm_f32(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def softmax_f32(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def gelu_f32(x: jnp.ndarray) -> jnp.ndarray:
    from jax.scipy.special import erf

    return 0.5 * x * (1.0 + erf(x / jnp.sqrt(2.0)))
