"""Pallas fused attention head — the QK MatMul → Softmax → RV MatMul chain.

This kernel is the hybrid-grained pipeline's hot spot expressed in Pallas
terms: the Q branch streams fine-grained (TP tokens per grid step) while
the K and V operands are *whole-tensor* blocks — the BlockSpec analogue of
the deep buffers of Sec. 4.2 (the buffer "is deep enough to hold the
entire K or V tensor", re-read for every output tile = COT re-reads). The
V operand arrives already transposed-in-access by the BlockSpec, the
Transpose Module analogue.

Numerics are identical to ref.attention_head_int (exact int equality).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    e_ent_ref,
    rs_ent_ref,
    rf_ent_ref,
    p_ent_ref,
    o_ref,
    *,
    e_alpha: int,
    e_shift: int,
    e_bits: int,
    pivot: int,
    rs_alpha: int,
    rs_shift: int,
    rs_bits: int,
    rf_alpha: int,
    rf_shift: int,
    rf_bits: int,
    ratio_log2: int,
    p_alpha: int,
    p_shift: int,
    p_bits: int,
):
    q = q_ref[...].astype(jnp.int32)  # (TP, dh)
    k = k_ref[...].astype(jnp.int32)  # (T, dh) — deep buffer
    v = v_ref[...].astype(jnp.int32)  # (T, dh) — deep buffer (transposed access)

    # QK MatMul (DyMM): scores (TP, T)
    scores = jnp.matmul(q, k.T, preferred_element_type=jnp.int32)

    # Softmax: max-subtract + inverted Exp LUT (Sec. 4.4.7)
    m = jnp.max(scores, axis=-1, keepdims=True)
    d = scores - m  # <= 0, beta anchored at 0
    ei = jnp.clip(jnp.right_shift(e_alpha - d, e_shift), 0, (1 << e_bits) - 1)
    e = jnp.take(e_ent_ref[...], ei)

    # row sum + segmented Recip LUT (Sec. 4.4.6)
    tot = jnp.sum(e, axis=-1, keepdims=True)
    si = jnp.clip(jnp.right_shift(tot - rs_alpha, rs_shift), 0, (1 << rs_bits) - 1)
    fi = jnp.clip(jnp.right_shift(tot - rf_alpha, rf_shift), 0, (1 << rf_bits) - 1)
    sv = jnp.left_shift(jnp.take(rs_ent_ref[...], si), ratio_log2)
    fv = jnp.take(rf_ent_ref[...], fi)
    r = jnp.where(tot < pivot, sv, fv)

    # probability ReQuant LUT
    pr = e * r
    pi = jnp.clip(jnp.right_shift(pr - p_alpha, p_shift), 0, (1 << p_bits) - 1)
    probs = jnp.take(p_ent_ref[...], pi)

    # RV MatMul (DyMM): (TP, T) @ (T, dh)
    o_ref[...] = jnp.matmul(probs, v, preferred_element_type=jnp.int32)


def attention_head(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    exp_lut,
    recip_seg,
    prob_lut,
    *,
    tp: int = 2,
) -> jnp.ndarray:
    """q,k,v: (T, dh) int32 -> (T, dh) int32 RV accumulator."""
    e_alpha, e_shift, e_bits, e_inv, e_ent = exp_lut
    assert e_inv, "softmax exp table must be inverted-indexed (Sec. 4.4.7)"
    pivot, steep, flat, ratio_log2 = recip_seg
    rs_alpha, rs_shift, rs_bits, _, rs_ent = steep
    rf_alpha, rf_shift, rf_bits, _, rf_ent = flat
    p_alpha, p_shift, p_bits, p_inv, p_ent = prob_lut
    assert not p_inv
    t, dh = q.shape
    assert k.shape == (t, dh) and v.shape == (t, dh)
    assert t % tp == 0

    return pl.pallas_call(
        functools.partial(
            _attn_kernel,
            e_alpha=e_alpha,
            e_shift=e_shift,
            e_bits=e_bits,
            pivot=pivot,
            rs_alpha=rs_alpha,
            rs_shift=rs_shift,
            rs_bits=rs_bits,
            rf_alpha=rf_alpha,
            rf_shift=rf_shift,
            rf_bits=rf_bits,
            ratio_log2=ratio_log2,
            p_alpha=p_alpha,
            p_shift=p_shift,
            p_bits=p_bits,
        ),
        grid=(t // tp,),
        in_specs=[
            pl.BlockSpec((tp, dh), lambda ti: (ti, 0)),  # Q: fine-grained stream
            pl.BlockSpec((t, dh), lambda ti: (0, 0)),  # K: deep buffer
            pl.BlockSpec((t, dh), lambda ti: (0, 0)),  # V: deep buffer
            pl.BlockSpec((int(e_ent.shape[0]),), lambda ti: (0,)),
            pl.BlockSpec((int(rs_ent.shape[0]),), lambda ti: (0,)),
            pl.BlockSpec((int(rf_ent.shape[0]),), lambda ti: (0,)),
            pl.BlockSpec((int(p_ent.shape[0]),), lambda ti: (0,)),
        ],
        out_specs=pl.BlockSpec((tp, dh), lambda ti: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, dh), jnp.int32),
        interpret=True,
    )(q.astype(jnp.int32), k.astype(jnp.int32), v.astype(jnp.int32), e_ent, rs_ent, rf_ent, p_ent)
