"""L1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .attention import attention_head  # noqa: F401
from .layernorm import layernorm_tiled  # noqa: F401
from .lut_ops import lut_apply_tiled, seg_apply_tiled  # noqa: F401
from .matmul_os import matmul_os  # noqa: F401
