"""Tiled Output-Stationary integer matmul — the StMM / DyMM PE (Sec. 4.3).

The accelerator tiles all three MM loops (Token, Output Channel, Input
Channel) with tile sizes TP/COP/CIP and keeps the partial sum stationary in
the PE while input-channel tiles stream through (Fig. 8). The Pallas grid
is exactly that loop nest: ``grid = (TT, COT, CIT)``; the output block is
revisited across the CIT axis, accumulating in place — Output Stationary.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and correctness (exact int equality vs ``ref.matmul_acc``)
is the contract here. On a real TPU this BlockSpec is also the VMEM
residency plan: the weight block (CIP x COP) is the BRAM ROM analogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, cit: int):
    """One (TP, COP) output tile; grid axis 2 streams CIP-wide input tiles."""
    ci_step = pl.program_id(2)

    @pl.when(ci_step == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...].astype(jnp.int32), o_ref.shape)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.matmul(x, w, preferred_element_type=jnp.int32)


def matmul_os(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    tp: int = 2,
    cip: int = 16,
    cop: int = 16,
) -> jnp.ndarray:
    """x:(T,CI) int32, w:(CI,CO) int32, bias:(CO,) -> (T,CO) int32 accumulator.

    tp/cip/cop are the Table-1 parallelism parameters (TP, CIP, COP); they
    must divide the corresponding dimensions (the parallelism designer in
    rust/src/arch guarantees this for every module of the network).
    """
    t, ci = x.shape
    ci2, co = w.shape
    assert ci == ci2, f"inner dims mismatch: {ci} vs {ci2}"
    assert t % tp == 0 and ci % cip == 0 and co % cop == 0, (
        f"tiling must divide dims: T={t}%{tp} CI={ci}%{cip} CO={co}%{cop}"
    )
    if bias is None:
        bias = jnp.zeros((co,), jnp.int32)
    tt, cit, cot = t // tp, ci // cip, co // cop

    return pl.pallas_call(
        functools.partial(_mm_kernel, cit=cit),
        grid=(tt, cot, cit),
        in_specs=[
            pl.BlockSpec((tp, cip), lambda ti, coi, cii: (ti, cii)),
            pl.BlockSpec((cip, cop), lambda ti, coi, cii: (cii, coi)),
            pl.BlockSpec((cop,), lambda ti, coi, cii: (coi,)),
        ],
        out_specs=pl.BlockSpec((tp, cop), lambda ti, coi, cii: (ti, coi)),
        out_shape=jax.ShapeDtypeStruct((t, co), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), w.astype(jnp.int32), bias.astype(jnp.int32))
