"""L2 — the quantized ViT (DeiT) model in the HG-PIPE integer dataflow.

The forward pass is written once, parameterized by an array module ``xp``
(numpy for calibration, jax.numpy for AOT lowering) and a *requant
strategy*:

  * ``AffineCalib`` — exact affine ReQuant (Eq. 4 computed in full
    precision). Used during calibration to record the integer accumulator
    ranges every LUT needs; it is also the "LUT-free" accuracy baseline of
    Fig. 11a (step "w/ LUT-based MACs").
  * ``LutExec``   — every non-linear operator is a PoT-indexed table
    (Sec. 4.4), exactly what the accelerator executes. jit-traceable.

Model structure follows DeiT with the paper's T=196 token grid (no class
token; mean-pool head), LN affine weights folded into the downstream MM
weights exactly as the HLS design folds them into the BRAM ROMs.

Quantization: all activations symmetric signed ``act_bits`` (probs
unsigned); weights symmetric signed ``weight_bits``; residual stream
carries 2 guard bits at the patch-embed output scale ``s0`` so all
residual adds are same-scale integer adds.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import numpy as np

from . import numerics, tables
from .quantize import QuantParams, calibrate_symmetric


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ViTConfig:
    name: str = "deit-tiny"
    img_size: int = 224
    patch: int = 16
    dim: int = 192
    depth: int = 12
    heads: int = 3
    mlp_ratio: int = 4
    num_classes: int = 1000
    act_bits: int = 4
    weight_bits: int = 4

    @property
    def tokens(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def hidden(self) -> int:
        return self.dim * self.mlp_ratio

    @property
    def ops_per_inference(self) -> int:
        """Op count (2 ops per MAC) — the paper's "OPs/inf" (2.5G tiny)."""
        t, d, h = self.tokens, self.dim, self.hidden
        per_block = (
            2 * t * d * (3 * d)  # QKV Gen
            + 2 * t * t * d * 2  # QK MatMul + RV MatMul
            + 2 * t * d * d  # Output Proj
            + 2 * t * d * h * 2  # MatMul1 + MatMul2
        )
        return (
            self.depth * per_block
            + 2 * t * self.patch_dim * d
            + 2 * self.dim * self.num_classes
        )


def deit_tiny(**kw) -> ViTConfig:
    return replace(ViTConfig(), **kw)


def deit_small(**kw) -> ViTConfig:
    return replace(ViTConfig(name="deit-small", dim=384, heads=6), **kw)


def tiny_synth(**kw) -> ViTConfig:
    """Trainable-on-CPU config for the accuracy-shape experiments."""
    return replace(
        ViTConfig(
            name="tiny-synth",
            img_size=32,
            patch=8,
            dim=64,
            depth=4,
            heads=2,
            mlp_ratio=4,
            num_classes=10,
        ),
        **kw,
    )


@dataclass(frozen=True)
class LutOptions:
    """Ablation switches — Fig. 11a ladder / Fig. 11b ablations."""

    inverted_exp: bool = True  # Sec. 4.4.7
    requant_calib: bool = True  # Sec. 4.4.5 on plain ReQuant tables
    gelu_calib: bool = True  # Sec. 4.4.5 on the fused GeLU table
    segmented_recip: bool = True  # Sec. 4.4.6


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(rng: np.random.Generator, cfg: ViTConfig) -> dict:
    """Float parameters (numpy f64), trunc-normal-ish init."""

    def w(shape, std=0.02):
        return rng.normal(0.0, std, size=shape)

    params = {
        "pe_w": w((cfg.patch_dim, cfg.dim)),
        "pe_b": np.zeros(cfg.dim),
        "head_w": w((cfg.dim, cfg.num_classes)),
        "head_b": np.zeros(cfg.num_classes),
        "ln_f_g": np.ones(cfg.dim),
        "ln_f_b": np.zeros(cfg.dim),
        "blocks": [],
    }
    for _ in range(cfg.depth):
        params["blocks"].append(
            {
                "ln1_g": np.ones(cfg.dim),
                "ln1_b": np.zeros(cfg.dim),
                "qkv_w": w((cfg.dim, 3 * cfg.dim)),
                "qkv_b": np.zeros(3 * cfg.dim),
                "proj_w": w((cfg.dim, cfg.dim)),
                "proj_b": np.zeros(cfg.dim),
                "ln2_g": np.ones(cfg.dim),
                "ln2_b": np.zeros(cfg.dim),
                "mm1_w": w((cfg.dim, cfg.hidden)),
                "mm1_b": np.zeros(cfg.hidden),
                "mm2_w": w((cfg.hidden, cfg.dim)),
                "mm2_b": np.zeros(cfg.dim),
            }
        )
    return params


def patchify(images: np.ndarray, cfg: ViTConfig):
    """(B, H, W, 3) -> (B, T, patch*patch*3)."""
    b, h, w, c = images.shape
    p = cfg.patch
    assert h == w == cfg.img_size and c == 3
    g = h // p
    x = images.reshape(b, g, p, g, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, p * p * c)


# ---------------------------------------------------------------------------
# float forward (numpy; calibration pass A + accuracy baseline)
# ---------------------------------------------------------------------------


def _ln_f(x, g, b, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps) * g + b


_erf_vec = np.vectorize(numerics.erf_approx)


def _gelu_f(x):
    # erf via the same fixed-constant approximation as the table generator
    return 0.5 * x * (1.0 + _erf_vec(x / math.sqrt(2.0)))


def _softmax_f(x):
    m = x.max(-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(-1, keepdims=True)


def forward_f32(params: dict, tokens: np.ndarray, cfg: ViTConfig, stats: dict | None = None):
    """Float reference forward over patchified tokens (B, T, P) -> logits.

    If ``stats`` is given, records per-site float ranges used to calibrate
    the activation quantizers (calibration pass A).
    """

    def rec(site, arr):
        if stats is not None:
            lo, hi = float(arr.min()), float(arr.max())
            # 99.9th percentile of |x|: outlier-robust activation ranges
            # (plain max stretches the 4-bit grid over one stray value)
            p = float(np.percentile(np.abs(arr), 99.9))
            plo, phi, pp = stats.get(site, (math.inf, -math.inf, 0.0))
            stats[site] = (min(lo, plo), max(hi, phi), max(p, pp))

    x = tokens @ params["pe_w"] + params["pe_b"]
    rec("pe_out", x)
    h, dh = cfg.heads, cfg.head_dim
    for i, blk in enumerate(params["blocks"]):
        n = _ln_f(x, blk["ln1_g"], blk["ln1_b"])
        rec(f"b{i}.ln1_out", n)
        qkv = n @ blk["qkv_w"] + blk["qkv_b"]
        rec(f"b{i}.qkv_out", qkv)
        b, t, _ = qkv.shape
        qkv = qkv.reshape(b, t, 3, h, dh).transpose(2, 0, 3, 1, 4)  # (3,B,H,T,dh)
        q, k, v = qkv[0], qkv[1], qkv[2]
        scores = q @ k.transpose(0, 1, 3, 2) / math.sqrt(dh)
        probs = _softmax_f(scores)
        rec(f"b{i}.probs", probs)
        a = probs @ v  # (B, H, T, dh)
        a = a.transpose(0, 2, 1, 3).reshape(b, t, cfg.dim)
        rec(f"b{i}.rv_out", a)
        o = a @ blk["proj_w"] + blk["proj_b"]
        rec(f"b{i}.proj_out", o)
        x = x + o
        n2 = _ln_f(x, blk["ln2_g"], blk["ln2_b"])
        rec(f"b{i}.ln2_out", n2)
        hdn = _gelu_f(n2 @ blk["mm1_w"] + blk["mm1_b"])
        rec(f"b{i}.gelu_out", hdn)
        o2 = hdn @ blk["mm2_w"] + blk["mm2_b"]
        rec(f"b{i}.mm2_out", o2)
        x = x + o2
    n = _ln_f(x, params["ln_f_g"], params["ln_f_b"])
    rec("ln_f_out", n)
    pooled = n.mean(axis=1)
    return pooled @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# quantized model container
# ---------------------------------------------------------------------------


@dataclass
class QuantModel:
    """Integer weights + LUT set + scale metadata for one precision config."""

    cfg: ViTConfig
    opts: LutOptions
    input_q: QuantParams
    s0: float  # residual-stream scale (pe-out activation scale)
    weights: dict  # int arrays
    luts: dict  # site -> tables.LutTable | tables.SegmentedTable
    scalars: dict  # site -> floats/ints (in_scales, guard shifts)
    act_params: dict  # site -> QuantParams

    def lut_count(self) -> int:
        n = 0
        for v in self.luts.values():
            n += 2 if isinstance(v, tables.SegmentedTable) else 1
        return n


# ---------------------------------------------------------------------------
# requant strategies
# ---------------------------------------------------------------------------


class AffineCalib:
    """Exact affine requant; records accumulator ranges for table building."""

    def __init__(self, act_params: dict, scalars: dict):
        self.act_params = act_params
        self.scalars = scalars
        self.ranges: dict[str, tuple[int, int]] = {}

    def obs(self, site, arr):
        lo, hi = int(arr.min()), int(arr.max())
        plo, phi = self.ranges.get(site, (2**62, -(2**62)))
        self.ranges[site] = (min(lo, plo), max(hi, phi))

    @staticmethod
    def _quant(real, out: QuantParams):
        q = np.where(
            real >= 0, np.floor(real / out.scale + 0.5), np.ceil(real / out.scale - 0.5)
        ).astype(np.int64)
        return np.clip(q, out.qmin, out.qmax)

    def requant(self, site, acc, in_scale, out: QuantParams):
        self.obs(site, acc)
        return self._quant(acc.astype(np.float64) * in_scale, out)

    def gelu(self, site, acc, in_scale, out: QuantParams):
        self.obs(site, acc)
        return self._quant(_gelu_f(acc.astype(np.float64) * in_scale), out)

    def layernorm(self, site, x, guard_shift, out: QuantParams):
        x = x.astype(np.int64)
        ci = x.shape[-1]
        s = x.sum(-1, keepdims=True)
        c = ci * x - s
        self.obs(site + ".c", np.abs(c))
        cg = c >> guard_shift
        v = (cg * cg).sum(-1, keepdims=True)
        self.obs(site + ".v", v)
        r = 1.0 / np.sqrt(
            np.maximum(v, 1).astype(np.float64) * (2.0 ** (2 * guard_shift)) / ci
        )
        y = c.astype(np.float64) * r
        # record the integer product range p = c * r_q for the ReQuant table
        rs = self.scalars.get(site + ".rsqrt_out_scale")
        if rs is not None:
            self.obs(site + ".p", (c * np.round(r / rs)).astype(np.int64))
        return self._quant(y, out)

    def softmax(self, site, scores, in_scale, out: QuantParams):
        scores = scores.astype(np.int64)
        m = scores.max(-1, keepdims=True)
        d = scores - m
        self.obs(site + ".d", d)
        e = np.exp(d.astype(np.float64) * in_scale)
        e_scale = self.scalars["exp_out_scale"]
        e_q = np.round(e / e_scale).astype(np.int64)
        tot = e_q.sum(-1, keepdims=True)
        self.obs(site + ".tot", tot)
        p = e / e.sum(-1, keepdims=True)
        r_scale = self.scalars.get(site + ".recip_out_scale")
        if r_scale is not None:
            r_q = np.round(
                (1.0 / np.maximum(tot * e_scale, 1e-12)) / r_scale
            ).astype(np.int64)
            self.obs(site + ".er", e_q * r_q)
        return self._quant(p, out)


class LutExec:
    """Table-based requant — the accelerator's semantics. Works for numpy
    and jax.numpy via the xp module handle."""

    def __init__(self, qm: "QuantModel", xp):
        self.qm = qm
        self.xp = xp

    def _i32(self, x):
        return x.astype(np.int32) if self.xp is np else x.astype("int32")

    def _lut(self, x, t: tables.LutTable):
        xp = self.xp
        ent = xp.asarray(np.asarray(t.entries, dtype=np.int32))
        x = self._i32(x)
        raw = (t.alpha - x) >> t.shift if t.inverted else (x - t.alpha) >> t.shift
        idx = xp.clip(raw, 0, t.depth - 1)
        return xp.take(ent, idx)

    def _seg(self, x, s: tables.SegmentedTable):
        xp = self.xp
        ratio = s.steep.out_scale / s.flat.out_scale
        rl2 = int(round(math.log2(ratio)))
        sv = self._lut(x, s.steep) << rl2
        fv = self._lut(x, s.flat)
        return xp.where(self._i32(x) < s.pivot, sv, fv)

    def requant(self, site, acc, in_scale, out):
        return self._lut(acc, self.qm.luts[site])

    def gelu(self, site, acc, in_scale, out):
        return self._lut(acc, self.qm.luts[site])

    def layernorm(self, site, x, guard_shift, out):
        xp = self.xp
        x = self._i32(x)
        ci = x.shape[-1]
        s = xp.sum(x, axis=-1, keepdims=True)
        c = ci * x - s
        cg = c >> guard_shift
        v = xp.sum(cg * cg, axis=-1, keepdims=True)
        r = self._lut(v, self.qm.luts[site + ".rsqrt"])
        return self._lut(c * r, self.qm.luts[site + ".rq"])

    def softmax(self, site, scores, in_scale, out):
        xp = self.xp
        scores = self._i32(scores)
        m = xp.max(scores, axis=-1, keepdims=True)
        e = self._lut(scores - m, self.qm.luts[site + ".exp"])
        tot = xp.sum(e, axis=-1, keepdims=True)
        recip = self.qm.luts[site + ".recip"]
        r = (
            self._seg(tot, recip)
            if isinstance(recip, tables.SegmentedTable)
            else self._lut(tot, recip)
        )
        return self._lut(e * r, self.qm.luts[site + ".prob"])


# ---------------------------------------------------------------------------
# the shared integer forward
# ---------------------------------------------------------------------------


def forward_int(qm: QuantModel, x_q, strategy, xp=np):
    """Integer forward over quantized tokens x_q (B, T, P) -> float logits.

    All linear algebra is plain integer matmul — identical between the
    strategies; only the requant sites differ.
    """
    cfg = qm.cfg
    W = qm.weights
    sc = qm.scalars
    ap = qm.act_params

    def _imm(a, b_op):
        # exact integer matmul through f64 BLAS: every partial sum here is
        # far below 2^53, so the double-precision dgemm result is exact and
        # ~100x faster than numpy's non-BLAS int64 path.
        return np.rint(a.astype(np.float64) @ b_op.astype(np.float64)).astype(np.int64)

    def mm(x, w, b):
        if xp is np:
            return _imm(x, np.asarray(w)) + np.asarray(b, np.int64)
        import jax.numpy as jnp

        return (
            jnp.matmul(x.astype(jnp.int32), jnp.asarray(w, jnp.int32),
                       preferred_element_type=jnp.int32)
            + jnp.asarray(b, jnp.int32)
        )

    def dyn_mm(a, b_op):
        if xp is np:
            return _imm(a, b_op)
        import jax.numpy as jnp

        return jnp.matmul(a.astype(jnp.int32), b_op.astype(jnp.int32),
                          preferred_element_type=jnp.int32)

    def tr(arr, axes):
        return arr.transpose(axes) if xp is np else xp.transpose(arr, axes)

    x = strategy.requant("pe", mm(x_q, W["pe_w"], W["pe_b"]), sc["pe.in_scale"], ap["pe_out"])
    h, dh = cfg.heads, cfg.head_dim

    for i in range(cfg.depth):
        p = f"b{i}"
        n = strategy.layernorm(f"{p}.ln1", x, sc[f"{p}.ln1.guard"], ap[f"{p}.ln1_out"])
        qkv = strategy.requant(
            f"{p}.qkv", mm(n, W[f"{p}.qkv_w"], W[f"{p}.qkv_b"]),
            sc[f"{p}.qkv.in_scale"], ap[f"{p}.qkv_out"],
        )
        b, t, _ = qkv.shape
        qkv = tr(qkv.reshape(b, t, 3, h, dh), (2, 0, 3, 1, 4))
        q, k, v = qkv[0], qkv[1], qkv[2]
        scores = dyn_mm(q, tr(k, (0, 1, 3, 2)))
        probs = strategy.softmax(f"{p}.attn", scores, sc[f"{p}.attn.in_scale"], ap[f"{p}.probs"])
        a = dyn_mm(probs, v)  # (B, H, T, dh)
        a = tr(a, (0, 2, 1, 3)).reshape(b, t, cfg.dim)
        a = strategy.requant(f"{p}.rv", a, sc[f"{p}.rv.in_scale"], ap[f"{p}.rv_out"])
        o = strategy.requant(
            f"{p}.proj", mm(a, W[f"{p}.proj_w"], W[f"{p}.proj_b"]),
            sc[f"{p}.proj.in_scale"], ap[f"{p}.res"],
        )
        x = x + o  # Residual Add module: same-scale integer add
        n2 = strategy.layernorm(f"{p}.ln2", x, sc[f"{p}.ln2.guard"], ap[f"{p}.ln2_out"])
        hdn = strategy.gelu(
            f"{p}.gelu", mm(n2, W[f"{p}.mm1_w"], W[f"{p}.mm1_b"]),
            sc[f"{p}.gelu.in_scale"], ap[f"{p}.gelu_out"],
        )
        o2 = strategy.requant(
            f"{p}.mm2", mm(hdn, W[f"{p}.mm2_w"], W[f"{p}.mm2_b"]),
            sc[f"{p}.mm2.in_scale"], ap[f"{p}.res"],
        )
        x = x + o2

    n = strategy.layernorm("ln_f", x, sc["ln_f.guard"], ap["ln_f_out"])
    pooled = xp.sum(n, axis=1)  # mean-pool: /T folded into logit scale
    if xp is np:
        logits_acc = _imm(pooled, np.asarray(W["head_w"]))
        logits = logits_acc.astype(np.float64) * sc["head.logit_scale"]
        return logits + W["head_b_f"]
    import jax.numpy as jnp

    logits_acc = jnp.matmul(
        pooled.astype(jnp.int32), jnp.asarray(W["head_w"], jnp.int32),
        preferred_element_type=jnp.int32,
    )
    logits = logits_acc.astype(jnp.float32) * jnp.float32(sc["head.logit_scale"])
    return logits + jnp.asarray(W["head_b_f"], jnp.float32)


# ---------------------------------------------------------------------------
# building the quantized model (calibration passes A + B, table generation)
# ---------------------------------------------------------------------------


def _sym(amax: float, bits: int) -> QuantParams:
    qmax = (1 << (bits - 1)) - 1
    return QuantParams(scale=max(amax, 1e-8) / qmax, zero_point=0, bits=bits, signed=True)


def _unsigned(amax: float, bits: int) -> QuantParams:
    qmax = (1 << bits) - 1
    return QuantParams(scale=max(amax, 1e-8) / qmax, zero_point=0, bits=bits, signed=False)


def _quantize_weights(params: dict, cfg: ViTConfig, act_params: dict, scalars: dict) -> dict:
    """Quantize weights with LN affine folding; fills in_scales; returns ints."""
    wbits = cfg.weight_bits
    W: dict = {}

    def fold_ln(gamma, beta, w, b):
        return gamma[:, None] * w, b + beta @ w

    def qw(name, w, b, in_scale):
        wq = calibrate_symmetric(w, wbits)
        W[name + "_w"] = wq.quantize(w)
        acc_scale = in_scale * wq.scale
        W[name + "_b"] = np.clip(np.round(b / acc_scale), -(2**30), 2**30).astype(np.int64)
        return acc_scale

    s_in = act_params["input"].scale
    scalars["pe.in_scale"] = qw("pe", params["pe_w"], params["pe_b"], s_in)

    for i, blk in enumerate(params["blocks"]):
        p = f"b{i}"
        w_qkv, b_qkv = fold_ln(blk["ln1_g"], blk["ln1_b"], blk["qkv_w"], blk["qkv_b"])
        scalars[f"{p}.qkv.in_scale"] = qw(
            f"{p}.qkv", w_qkv, b_qkv, act_params[f"{p}.ln1_out"].scale
        )
        scalars[f"{p}.proj.in_scale"] = qw(
            f"{p}.proj", blk["proj_w"], blk["proj_b"], act_params[f"{p}.rv_out"].scale
        )
        w1, b1 = fold_ln(blk["ln2_g"], blk["ln2_b"], blk["mm1_w"], blk["mm1_b"])
        scalars[f"{p}.gelu.in_scale"] = qw(
            f"{p}.mm1", w1, b1, act_params[f"{p}.ln2_out"].scale
        )
        scalars[f"{p}.mm2.in_scale"] = qw(
            f"{p}.mm2", blk["mm2_w"], blk["mm2_b"], act_params[f"{p}.gelu_out"].scale
        )
        sq = act_params[f"{p}.qkv_out"].scale
        scalars[f"{p}.attn.in_scale"] = sq * sq / math.sqrt(cfg.head_dim)
        scalars[f"{p}.rv.in_scale"] = act_params[f"{p}.probs"].scale * sq

    wh, bh = fold_ln(params["ln_f_g"], params["ln_f_b"], params["head_w"], params["head_b"])
    whq = calibrate_symmetric(wh, wbits)
    W["head_w"] = whq.quantize(wh)
    W["head_b_f"] = bh.astype(np.float32)
    scalars["head.w_scale"] = whq.scale
    return W


def _guard_shift(cmax: int, ci: int) -> int:
    """Smallest g with (cmax>>g)^2 * ci < 2^31 (int32-safe variance acc)."""
    g = 0
    while ((cmax >> g) ** 2) * ci >= (1 << 31):
        g += 1
    return g


def build_quantized(
    params: dict,
    cfg: ViTConfig,
    calib_tokens: np.ndarray,
    opts: LutOptions = LutOptions(),
) -> QuantModel:
    """Post-training quantization + LUT generation (the build-time pipeline).

    calib_tokens: (B, T, P) float patchified calibration batch.
    """
    # ---- pass A: float forward, activation ranges ------------------------
    stats: dict = {}
    forward_f32(params, calib_tokens, cfg, stats=stats)
    ab = cfg.act_bits

    act_params: dict = {"input": _sym(float(np.abs(calib_tokens).max()), ab)}
    for site, (lo, hi, p999) in stats.items():
        amax = p999  # outlier-robust
        if site.endswith(".probs"):
            act_params[site] = _unsigned(min(max(abs(lo), abs(hi)), 1.0), ab)
        else:
            act_params[site] = _sym(amax, ab)
    # residual stream: common scale s0 with 2 guard bits
    s0 = act_params["pe_out"].scale
    res_q = QuantParams(scale=s0, zero_point=0, bits=ab + 2, signed=True)
    for i in range(cfg.depth):
        act_params[f"b{i}.res"] = res_q

    # ---- weight quantization ---------------------------------------------
    scalars: dict = {}
    W = _quantize_weights(params, cfg, act_params, scalars)

    scalars["exp_out_scale"] = 1.0 / ((1 << tables.EXP_OUT_BITS) - 1)

    # LN guard shifts from static worst-case c ranges.
    for i in range(cfg.depth):
        span1 = (2 * i + 1) * res_q.qmax if i > 0 else act_params["pe_out"].qmax
        span2 = (2 * i + 2) * res_q.qmax
        scalars[f"b{i}.ln1.guard"] = _guard_shift(2 * span1 * cfg.dim, cfg.dim)
        scalars[f"b{i}.ln2.guard"] = _guard_shift(2 * span2 * cfg.dim, cfg.dim)
    scalars["ln_f.guard"] = _guard_shift(
        2 * (2 * cfg.depth + 1) * res_q.qmax * cfg.dim, cfg.dim
    )
    # head logit scale: s_lnf_out * w_scale / T (mean pool folded)
    scalars["head.logit_scale"] = float(
        act_params["ln_f_out"].scale * scalars["head.w_scale"] / cfg.tokens
    )

    qm = QuantModel(
        cfg=cfg,
        opts=opts,
        input_q=act_params["input"],
        s0=s0,
        weights=W,
        luts={},
        scalars=scalars,
        act_params=act_params,
    )

    # ---- pass B round 1: affine forward, primary accumulator ranges -------
    calib = AffineCalib(act_params, scalars)
    x_q = act_params["input"].quantize(calib_tokens)
    forward_int(qm, x_q, calib, xp=np)
    r1 = dict(calib.ranges)

    # derive rsqrt/recip output scales, then round 2 observes the dependent
    # integer products (p = c*r, er = e*r).
    ln_sites = [f"b{i}.ln{j}" for i in range(cfg.depth) for j in (1, 2)] + ["ln_f"]
    for s in ln_sites:
        guard = scalars[s + ".guard"]
        in_scale = (2.0 ** (2 * guard)) / cfg.dim
        vmin, _ = r1[s + ".v"]
        rs_max = 1.0 / math.sqrt(max(vmin, 1) * in_scale)
        scalars[s + ".rsqrt_out_scale"] = tables.pot_out_scale(rs_max, tables.RSQRT_OUT_BITS)
        scalars[s + ".rsqrt_in_scale"] = in_scale
    for i in range(cfg.depth):
        s = f"b{i}.attn"
        tmin, tmax = r1[s + ".tot"]
        e_scale = scalars["exp_out_scale"]
        span = max(tmax - max(tmin, 1), 8)
        pivot = max(tmin, 1) + max(span >> 3, 1)
        # the finer (flat-segment) scale is the common recip output scale
        scalars[s + ".recip_out_scale"] = tables.pot_out_scale(
            1.0 / (pivot * e_scale), tables.RECIP_OUT_BITS
        )

    calib2 = AffineCalib(act_params, scalars)
    forward_int(qm, x_q, calib2, xp=np)
    ranges = calib2.ranges

    # ---- build all tables ---------------------------------------------------
    def rq_table(site, alpha, beta, in_scale, out):
        if opts.requant_calib:
            return tables.joint_calibrate(
                site, lambda x: x, alpha, beta, in_scale, tables.REQUANT_BITS, out
            )
        return tables.requant_table(site, alpha, beta, in_scale, out)

    luts = qm.luts
    lo, hi = ranges["pe"]
    luts["pe"] = rq_table("pe", lo, hi, scalars["pe.in_scale"], act_params["pe_out"])

    for i in range(cfg.depth):
        p = f"b{i}"
        for ln, out_site in ((f"{p}.ln1", f"{p}.ln1_out"), (f"{p}.ln2", f"{p}.ln2_out")):
            vmin, vmax = ranges[ln + ".v"]
            luts[ln + ".rsqrt"] = tables.rsqrt_table(
                ln + ".rsqrt", max(vmin, 1), max(vmax, 2), scalars[ln + ".rsqrt_in_scale"]
            )
            pmin, pmax = ranges[ln + ".p"]
            luts[ln + ".rq"] = rq_table(
                ln + ".rq", pmin, pmax, scalars[ln + ".rsqrt_out_scale"], act_params[out_site]
            )
        lo, hi = ranges[f"{p}.qkv"]
        luts[f"{p}.qkv"] = rq_table(
            f"{p}.qkv", lo, hi, scalars[f"{p}.qkv.in_scale"], act_params[f"{p}.qkv_out"]
        )
        # softmax tables
        a = f"{p}.attn"
        dmin, _ = ranges[a + ".d"]
        if opts.inverted_exp:
            luts[a + ".exp"] = tables.exp_table_inverted(
                a + ".exp", dmin, 0, scalars[a + ".in_scale"]
            )
        else:
            luts[a + ".exp"] = tables.exp_table_normal(
                a + ".exp", dmin, 0, scalars[a + ".in_scale"]
            )
        tmin, tmax = ranges[a + ".tot"]
        if opts.segmented_recip:
            luts[a + ".recip"] = tables.recip_table_segmented(
                a + ".recip", max(tmin, 1), max(tmax, 16), scalars["exp_out_scale"]
            )
            r_fine = luts[a + ".recip"].flat.out_scale
        else:
            luts[a + ".recip"] = tables.recip_table_flat(
                a + ".recip", max(tmin, 1), max(tmax, 16), scalars["exp_out_scale"]
            )
            r_fine = luts[a + ".recip"].out_scale
        ermin, ermax = ranges[a + ".er"]
        luts[a + ".prob"] = rq_table(
            a + ".prob",
            max(ermin, 0),
            max(ermax, 16),
            scalars["exp_out_scale"] * r_fine,
            act_params[f"{p}.probs"],
        )
        lo, hi = ranges[f"{p}.rv"]
        luts[f"{p}.rv"] = rq_table(
            f"{p}.rv", lo, hi, scalars[f"{p}.rv.in_scale"], act_params[f"{p}.rv_out"]
        )
        lo, hi = ranges[f"{p}.proj"]
        luts[f"{p}.proj"] = rq_table(
            f"{p}.proj", lo, hi, scalars[f"{p}.proj.in_scale"], act_params[f"{p}.res"]
        )
        lo, hi = ranges[f"{p}.gelu"]
        if opts.gelu_calib:
            luts[f"{p}.gelu"] = tables.joint_calibrate(
                f"{p}.gelu", numerics.gelu, lo, hi, scalars[f"{p}.gelu.in_scale"],
                tables.GELU_BITS, act_params[f"{p}.gelu_out"],
            )
        else:
            luts[f"{p}.gelu"] = tables.gelu_requant_table(
                f"{p}.gelu", lo, hi, scalars[f"{p}.gelu.in_scale"], act_params[f"{p}.gelu_out"]
            )
        lo, hi = ranges[f"{p}.mm2"]
        luts[f"{p}.mm2"] = rq_table(
            f"{p}.mm2", lo, hi, scalars[f"{p}.mm2.in_scale"], act_params[f"{p}.res"]
        )

    vmin, vmax = ranges["ln_f.v"]
    luts["ln_f.rsqrt"] = tables.rsqrt_table(
        "ln_f.rsqrt", max(vmin, 1), max(vmax, 2), scalars["ln_f.rsqrt_in_scale"]
    )
    pmin, pmax = ranges["ln_f.p"]
    luts["ln_f.rq"] = rq_table(
        "ln_f.rq", pmin, pmax, scalars["ln_f.rsqrt_out_scale"], act_params["ln_f_out"]
    )
    return qm


# ---------------------------------------------------------------------------
# jnp execution wrappers (AOT entry points)
# ---------------------------------------------------------------------------


def forward_int_jnp(qm: QuantModel, x_q):
    """jit-traceable LUT-exact forward (the artifact the rust runtime loads)."""
    import jax.numpy as jnp

    return forward_int(qm, x_q, LutExec(qm, jnp), xp=jnp)


def forward_int_np(qm: QuantModel, x_q):
    """numpy LUT-exact forward (must equal forward_int_jnp exactly)."""
    return forward_int(qm, x_q, LutExec(qm, np), xp=np)


def quantize_input_jnp(qm: QuantModel, x_tokens):
    import jax.numpy as jnp

    q = qm.input_q
    scaled = x_tokens / jnp.float32(q.scale)
    r = jnp.where(scaled >= 0, jnp.floor(scaled + 0.5), jnp.ceil(scaled - 0.5))
    return jnp.clip(r, q.qmin, q.qmax).astype(jnp.int32)


def end_to_end_jnp(qm: QuantModel, x_tokens):
    """float tokens in, float logits out — the DMA-to-DMA computation."""
    return forward_int_jnp(qm, quantize_input_jnp(qm, x_tokens))
