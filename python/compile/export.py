"""Export a QuantModel as a self-contained *bundle* for the rust
interpreter backend (``rust/src/runtime/interpreter.rs``).

The PJRT path ships opaque HLO text; the interpreter instead executes the
integer dataflow directly from the quantized weights + LUT set, so the
bundle is plain JSON: integer weight/bias tensors (row-major flat lists),
the LUT tables in the same ``{"kind", "data"}`` wire format as
``tables.dump_tables``, the LayerNorm guard shifts, and the three floats
the head needs (input scale, logit scale, float bias). Python's
``json.dump`` emits shortest-round-trip reprs and rust's ``str::parse``
is correctly rounded, so every f64 crosses the boundary bit-exactly.

The *golden fixture* (``emit_golden``) freezes a fixed-seed tiny-synth
model, an eval batch, and the numpy-reference logits
(``model.forward_int_np``) into ``rust/artifacts/`` so ``cargo test``
asserts bit-exact interpreter agreement without ``make artifacts`` or a
jax install.

CLI:  python -m compile.export --out ../rust/artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import pickle

import numpy as np

from . import model as M
from . import tables
from .quantize import QuantParams

BUNDLE_FORMAT = "hgpipe-bundle-v1"

# batch variants the serving batcher dispatches (mirrors the PJRT
# per-batch executables; the interpreter handles any batch, these are the
# sizes the BatchPolicy chooses between)
BUNDLE_BATCHES = [1, 8]


def _ints(arr) -> list:
    return [int(v) for v in np.asarray(arr).reshape(-1)]


def bundle_dict(qm: M.QuantModel) -> dict:
    """QuantModel -> JSON-serializable bundle."""
    cfg = qm.cfg
    W, sc = qm.weights, qm.scalars

    weights = {"pe_w": _ints(W["pe_w"]), "pe_b": _ints(W["pe_b"])}
    guards = {}
    for i in range(cfg.depth):
        p = f"b{i}"
        for nm in ("qkv", "proj", "mm1", "mm2"):
            weights[f"{p}.{nm}_w"] = _ints(W[f"{p}.{nm}_w"])
            weights[f"{p}.{nm}_b"] = _ints(W[f"{p}.{nm}_b"])
        guards[f"{p}.ln1"] = int(sc[f"{p}.ln1.guard"])
        guards[f"{p}.ln2"] = int(sc[f"{p}.ln2.guard"])
    guards["ln_f"] = int(sc["ln_f.guard"])
    weights["head_w"] = _ints(W["head_w"])

    luts = {}
    for k, v in qm.luts.items():
        kind = "segmented" if isinstance(v, tables.SegmentedTable) else "lut"
        luts[k] = {"kind": kind, "data": v.to_dict()}

    return {
        "format": BUNDLE_FORMAT,
        "model": cfg.name,
        "precision": f"a{cfg.act_bits}w{cfg.weight_bits}",
        "cfg": {
            "tokens": cfg.tokens,
            "patch_dim": cfg.patch_dim,
            "dim": cfg.dim,
            "depth": cfg.depth,
            "heads": cfg.heads,
            "hidden": cfg.hidden,
            "num_classes": cfg.num_classes,
        },
        "input": {
            "scale": float(qm.input_q.scale),
            "qmin": int(qm.input_q.qmin),
            "qmax": int(qm.input_q.qmax),
        },
        "head": {
            "logit_scale": float(sc["head.logit_scale"]),
            # float32 biases, widened exactly to f64 for JSON
            "bias": [float(b) for b in W["head_b_f"]],
        },
        "guards": guards,
        "weights": weights,
        "luts": luts,
    }


def export_bundle(qm: M.QuantModel, path: str) -> dict:
    """Write the bundle and return its manifest entry."""
    d = bundle_dict(qm)
    with open(path, "w") as f:
        json.dump(d, f, sort_keys=True)
    cfg = qm.cfg
    return {
        "path": os.path.basename(path),
        "model": cfg.name,
        "precision": d["precision"],
        "input": [cfg.tokens, cfg.patch_dim],
        "output": [cfg.num_classes],
        "batches": BUNDLE_BATCHES,
    }


# ---------------------------------------------------------------------------
# golden table fixture (rust lut::generate cross-check)
# ---------------------------------------------------------------------------


def golden_fixture() -> dict:
    """Deterministic table-generation cases. in_scales are exact binary
    fractions so both languages see identical f64 inputs; entries may vary
    by ±1 LSB where libm exp/sqrt differ by an ulp."""
    out_q = QuantParams(scale=0.125, zero_point=0, bits=4, signed=True)
    cases = {}

    t = tables.requant_table("rq", -1000, 2000, 0.03125, out_q)
    cases["requant"] = {"spec": {"alpha": -1000, "beta": 2000, "in_scale": 0.03125,
                                 "out": {"scale": 0.125, "bits": 4, "signed": True}},
                        "table": t.to_dict()}
    t = tables.joint_calibrate("rq_cal", lambda x: x, -4000, 4000, 0.03125, 6, out_q)
    cases["requant_calibrated"] = {"spec": {"alpha": -4000, "beta": 4000, "in_scale": 0.03125},
                                   "table": t.to_dict()}
    t = tables.gelu_requant_table("gelu", -800, 800, 0.0078125, out_q)
    cases["gelu"] = {"spec": {"alpha": -800, "beta": 800, "in_scale": 0.0078125},
                     "table": t.to_dict()}
    t = tables.exp_table_inverted("exp", -5000, 0, 0.001953125)
    cases["exp_inverted"] = {"spec": {"alpha": -5000, "beta": 0, "in_scale": 0.001953125},
                             "table": t.to_dict()}
    s = tables.recip_table_segmented("recip", 200, 40000, 0.00390625)
    cases["recip_segmented"] = {"spec": {"alpha": 200, "beta": 40000, "in_scale": 0.00390625},
                                "table": s.to_dict()}
    t = tables.rsqrt_table("rsqrt", 50, 100000, 0.0625)
    cases["rsqrt"] = {"spec": {"alpha": 50, "beta": 100000, "in_scale": 0.0625},
                      "table": t.to_dict()}
    return cases


# ---------------------------------------------------------------------------
# golden fixture for the rust interpreter tests (committed to the repo)
# ---------------------------------------------------------------------------


def golden_model(train_steps: int = 400, params_cache: str | None = None):
    """The frozen tiny-synth QuantModel behind the golden fixture."""
    from .train import synth_images, train

    cfg = M.tiny_synth()
    float_acc = None
    if params_cache and os.path.exists(params_cache):
        with open(params_cache, "rb") as f:
            blob = pickle.load(f)
        params, float_acc = blob["params"], blob.get("float_acc")
    elif train_steps > 0:
        params, _, float_acc = train(cfg, steps=train_steps)
        if params_cache:
            os.makedirs(os.path.dirname(params_cache) or ".", exist_ok=True)
            with open(params_cache, "wb") as f:
                pickle.dump({"params": params, "float_acc": float_acc}, f)
    else:
        # untrained fallback: still a valid bit-exactness fixture
        params = M.init_params(np.random.default_rng(42), cfg)
    calib_imgs, _ = synth_images(np.random.default_rng(42), 64)
    calib_toks = M.patchify(calib_imgs, cfg)
    qm = M.build_quantized(params, cfg, calib_toks)
    return qm, float_acc


def emit_golden(outdir: str, qm: M.QuantModel, eval_n: int = 64,
                float_acc: float | None = None) -> dict:
    """Write bundle + eval batch + reference logits into ``outdir``.

    The reference logits come from ``forward_int_np`` — the numpy LUT-exact
    path the interpreter mirrors — computed over the *float32* tokens the
    rust side will read back from ``golden_tokens.bin``.
    """
    from .train import synth_images

    os.makedirs(outdir, exist_ok=True)
    cfg = qm.cfg
    eval_imgs, eval_ys = synth_images(np.random.default_rng(7), eval_n)
    toks32 = M.patchify(eval_imgs, cfg).astype("<f4")
    # quantize from the f32 values (widened to f64) — exactly what the
    # interpreter sees after reading the .bin back
    x_q = qm.input_q.quantize(toks32.astype(np.float64))
    logits = np.asarray(M.forward_int_np(qm, x_q), dtype="<f8")
    acc = float((logits.argmax(1) == eval_ys).mean())

    with open(os.path.join(outdir, "golden_tokens.bin"), "wb") as f:
        f.write(toks32.tobytes())
    with open(os.path.join(outdir, "golden_logits.bin"), "wb") as f:
        f.write(logits.tobytes())
    with open(os.path.join(outdir, "golden_labels.bin"), "wb") as f:
        f.write(eval_ys[:eval_n].astype("u1").tobytes())

    with open(os.path.join(outdir, "golden_tables.json"), "w") as f:
        json.dump(golden_fixture(), f, indent=1, sort_keys=True)

    entry = export_bundle(qm, os.path.join(outdir, "tinyvit_bundle.json"))
    manifest = {
        "artifacts": {},
        "bundles": {"tinyvit_bundle": entry},
        "eval_set": {
            "tokens": "golden_tokens.bin",
            "labels": "golden_labels.bin",
            "count": eval_n,
            "shape": [eval_n, cfg.tokens, cfg.patch_dim],
        },
        "golden": {
            "tokens": "golden_tokens.bin",
            "logits": "golden_logits.bin",
            "labels": "golden_labels.bin",
            "count": eval_n,
            "quant_acc": acc,
            "float_acc": float_acc,
        },
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/artifacts/golden")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--eval-n", type=int, default=64)
    ap.add_argument("--params-cache", default=None)
    args = ap.parse_args()
    qm, float_acc = golden_model(args.train_steps, args.params_cache)
    m = emit_golden(args.out, qm, eval_n=args.eval_n, float_acc=float_acc)
    g = m["golden"]
    print(f"golden fixture in {args.out}: {g['count']} images, "
          f"quantized acc {g['quant_acc']:.4f} (float {float_acc})")


if __name__ == "__main__":
    main()
