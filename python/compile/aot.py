"""AOT build pipeline: train/calibrate/quantize → lower to HLO text.

Emits into ``artifacts/`` (all consumed by the rust side; python never runs
at request time):

  * ``deit_tiny_a4w4_b{1,8}.hlo.txt``  — full quantized DeiT-tiny forward
    (float tokens in, float logits out), batch 1 and 8 variants for the
    serving batcher.
  * ``deit_tiny_block_pallas.hlo.txt`` — one encoder block lowered through
    the L1 *Pallas kernels* (StMM tiles, LUT ops, fused attention head),
    proving the kernel → HLO → PJRT path.
  * ``tinyvit_int.hlo.txt``            — trained tiny-ViT (synthetic
    10-class), used by the rust accuracy harness.
  * ``tables_deit_tiny_a4w4.json`` (+a3w3) — the full LUT set.
  * ``golden_tables.json``             — deterministic fixture the rust
    table generator must reproduce (golden cross-check).
  * ``accuracy_ladder.json``           — Fig. 11a ladder + Fig. 11b
    ablations measured on the tiny-ViT.
  * ``manifest.json`` / ``quant_report.json`` — metadata for the runtime.

HLO **text** is the interchange format (NOT serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tables
from .export import export_bundle, golden_fixture
from .kernels import ref
from .quantize import QuantParams


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default elides big weight tensors as
    # "{...}", which the HLO text parser cannot reload — the weights ARE
    # the model, so print them in full.
    return comp.as_hlo_text(print_large_constants=True)


def lower_to_file(fn, example_args, path: str) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {"path": os.path.basename(path), "bytes": len(text), "lower_s": round(time.time() - t0, 2)}


# ---------------------------------------------------------------------------
# block-level pallas artifact
# ---------------------------------------------------------------------------


def block_pallas_fn(qm, block: int = 0):
    """One encoder block through the L1 Pallas kernels (x_q int32 (T,D))."""
    from .kernels import attention_head, layernorm_tiled, lut_apply_tiled, matmul_os

    cfg = qm.cfg
    p = f"b{block}"
    sc, W, L = qm.scalars, qm.weights, qm.luts
    h, dh = cfg.heads, cfg.head_dim
    t = cfg.tokens

    ln1_rs = ref.lut_params(L[f"{p}.ln1.rsqrt"])
    ln1_rq = ref.lut_params(L[f"{p}.ln1.rq"])
    qkv_rq = ref.lut_params(L[f"{p}.qkv"])
    exp_l = ref.lut_params(L[f"{p}.attn.exp"])
    recip_s = ref.seg_params(L[f"{p}.attn.recip"])
    prob_l = ref.lut_params(L[f"{p}.attn.prob"])
    rv_rq = ref.lut_params(L[f"{p}.rv"])
    proj_rq = ref.lut_params(L[f"{p}.proj"])
    ln2_rs = ref.lut_params(L[f"{p}.ln2.rsqrt"])
    ln2_rq = ref.lut_params(L[f"{p}.ln2.rq"])
    gelu_l = ref.lut_params(L[f"{p}.gelu"])
    mm2_rq = ref.lut_params(L[f"{p}.mm2"])

    wqkv = jnp.asarray(W[f"{p}.qkv_w"], jnp.int32)
    bqkv = jnp.asarray(W[f"{p}.qkv_b"], jnp.int32)
    wproj = jnp.asarray(W[f"{p}.proj_w"], jnp.int32)
    bproj = jnp.asarray(W[f"{p}.proj_b"], jnp.int32)
    w1 = jnp.asarray(W[f"{p}.mm1_w"], jnp.int32)
    b1 = jnp.asarray(W[f"{p}.mm1_b"], jnp.int32)
    w2 = jnp.asarray(W[f"{p}.mm2_w"], jnp.int32)
    b2 = jnp.asarray(W[f"{p}.mm2_b"], jnp.int32)

    def fn(x):
        # MHA block — Table 1 parallelism (TP=2; CIP/COP per module)
        n = layernorm_tiled(x, sc[f"{p}.ln1.guard"], ln1_rs, ln1_rq, tp=2)
        qkv = matmul_os(n, wqkv, bqkv, tp=2, cip=cfg.dim // 2, cop=cfg.dim // 2)
        qkv = lut_apply_tiled(qkv, qkv_rq, tp=2)
        heads = []
        for hi in range(h):
            q = qkv[:, hi * dh : (hi + 1) * dh]
            k = qkv[:, cfg.dim + hi * dh : cfg.dim + (hi + 1) * dh]
            v = qkv[:, 2 * cfg.dim + hi * dh : 2 * cfg.dim + (hi + 1) * dh]
            heads.append(attention_head(q, k, v, exp_l, recip_s, prob_l, tp=2))
        a = jnp.concatenate(heads, axis=-1)
        a = lut_apply_tiled(a, rv_rq, tp=2)
        o = matmul_os(a, wproj, bproj, tp=2, cip=cfg.dim // 2, cop=cfg.dim // 2)
        o = lut_apply_tiled(o, proj_rq, tp=2)
        x = x + o
        # MLP block
        n2 = layernorm_tiled(x, sc[f"{p}.ln2.guard"], ln2_rs, ln2_rq, tp=2)
        hd = matmul_os(n2, w1, b1, tp=2, cip=cfg.dim // 2, cop=cfg.hidden // 2)
        hd = lut_apply_tiled(hd, gelu_l, tp=2)
        o2 = matmul_os(hd, w2, b2, tp=2, cip=cfg.hidden // 2, cop=cfg.dim // 2)
        o2 = lut_apply_tiled(o2, mm2_rq, tp=2)
        return (x + o2,)

    return fn, jax.ShapeDtypeStruct((t, cfg.dim), jnp.int32)


# ---------------------------------------------------------------------------
# accuracy ladder + ablations (Fig. 11a / 11b) on the tiny-ViT
# ---------------------------------------------------------------------------


LADDER = [
    # (step name matching Fig. 11a, LutOptions or special mode)
    ("fp32", "float"),
    ("lut_mac", "affine"),  # LUT MAC units, exact non-linears
    ("pot_lut", M.LutOptions(False, False, False, False)),
    ("+inverted_exp", M.LutOptions(True, False, False, False)),
    ("+requant_calib", M.LutOptions(True, True, False, False)),
    ("+gelu_calib", M.LutOptions(True, True, True, False)),
    ("+segmented_recip", M.LutOptions(True, True, True, True)),
]

ABLATIONS = [
    ("w/o inverted_exp", M.LutOptions(False, True, True, True)),
    ("w/o requant_calib", M.LutOptions(True, False, True, True)),
    ("w/o gelu_calib", M.LutOptions(True, True, False, True)),
    ("w/o segmented_recip", M.LutOptions(True, True, True, False)),
]


def measure_accuracy(params, cfg, calib_toks, eval_toks, eval_ys) -> dict:
    from .model import AffineCalib, build_quantized, forward_f32, forward_int

    out = {"ladder": {}, "ablation": {}}

    def acc_of(logits):
        return float((np.asarray(logits).argmax(1) == eval_ys).mean())

    for name, mode in LADDER:
        if mode == "float":
            out["ladder"][name] = acc_of(forward_f32(params, eval_toks, cfg))
            continue
        qm = build_quantized(params, cfg, calib_toks, opts=M.LutOptions())
        xq = qm.input_q.quantize(eval_toks)
        if mode == "affine":
            strat = AffineCalib(qm.act_params, qm.scalars)
            out["ladder"][name] = acc_of(forward_int(qm, xq, strat, xp=np))
            continue
        qm = build_quantized(params, cfg, calib_toks, opts=mode)
        out["ladder"][name] = acc_of(M.forward_int_np(qm, qm.input_q.quantize(eval_toks)))

    for name, opts in ABLATIONS:
        qm = build_quantized(params, cfg, calib_toks, opts=opts)
        out["ablation"][name] = acc_of(M.forward_int_np(qm, qm.input_q.quantize(eval_toks)))
    return out


# ---------------------------------------------------------------------------
# main build
# ---------------------------------------------------------------------------


def dump_qm_tables(qm, path):
    tables.dump_tables(qm.luts, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--calib-batch", type=int, default=4)
    ap.add_argument("--quick", action="store_true", help="skip deit artifacts (tests only)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest: dict = {"artifacts": {}, "models": {}}
    rng = np.random.default_rng(42)

    # ---- golden table fixture -------------------------------------------
    with open(os.path.join(outdir, "golden_tables.json"), "w") as f:
        json.dump(golden_fixture(), f, indent=1, sort_keys=True)
    print("wrote golden_tables.json")

    # ---- tiny-ViT: train, accuracy ladder, artifact ----------------------
    from .train import synth_images, train

    tcfg = M.tiny_synth()
    cache = os.path.join(outdir, "tinyvit_params.pkl")
    if os.path.exists(cache):
        with open(cache, "rb") as f:
            blob = pickle.load(f)
        tparams, float_acc = blob["params"], blob["float_acc"]
        print(f"loaded cached tiny-ViT params (float acc {float_acc:.4f})")
    else:
        tparams, losses, float_acc = train(tcfg, steps=args.train_steps)
        with open(cache, "wb") as f:
            pickle.dump({"params": tparams, "losses": losses, "float_acc": float_acc}, f)

    calib_imgs, _ = synth_images(rng, 64)
    calib_toks = M.patchify(calib_imgs, tcfg)
    eval_imgs, eval_ys = synth_images(np.random.default_rng(7), 1000)
    eval_toks = M.patchify(eval_imgs, tcfg)

    for bits in (4, 3):
        cfgb = M.tiny_synth(act_bits=bits, weight_bits=bits)
        acc = measure_accuracy(tparams, cfgb, calib_toks, eval_toks, eval_ys)
        acc["float_acc"] = float_acc
        key = f"a{bits}w{bits}"
        manifest["models"].setdefault("tinyvit", {})[key] = acc
        print(f"tinyvit {key}: ladder={acc['ladder']}")
    with open(os.path.join(outdir, "accuracy_ladder.json"), "w") as f:
        json.dump(manifest["models"]["tinyvit"], f, indent=1, sort_keys=True)

    # evaluation batch for the rust-side accuracy harness: raw f32 tokens
    # + u8 labels (no numpy at runtime — plain little-endian binary)
    eval_n = 512
    toks512 = eval_toks[:eval_n].astype("<f4")
    with open(os.path.join(outdir, "eval_tokens.bin"), "wb") as f:
        f.write(toks512.tobytes())
    with open(os.path.join(outdir, "eval_labels.bin"), "wb") as f:
        f.write(eval_ys[:eval_n].astype("u1").tobytes())
    manifest["eval_set"] = {
        "tokens": "eval_tokens.bin",
        "labels": "eval_labels.bin",
        "count": eval_n,
        "shape": [eval_n, tcfg.tokens, tcfg.patch_dim],
    }

    # tiny-ViT serving artifact (full LUT pipeline, batch 16)
    qm_t = M.build_quantized(tparams, tcfg, calib_toks)
    info = lower_to_file(
        lambda x: (M.end_to_end_jnp(qm_t, x),),
        [jax.ShapeDtypeStruct((16, tcfg.tokens, tcfg.patch_dim), jnp.float32)],
        os.path.join(outdir, "tinyvit_int.hlo.txt"),
    )
    manifest["artifacts"]["tinyvit_int"] = {
        **info,
        "input": [16, tcfg.tokens, tcfg.patch_dim],
        "output": [16, tcfg.num_classes],
        "model": "tiny-synth", "precision": "a4w4",
    }
    dump_qm_tables(qm_t, os.path.join(outdir, "tables_tinyvit_a4w4.json"))
    # interpreter-backend bundle (the default rust execution path)
    manifest["bundles"] = {
        "tinyvit_bundle": export_bundle(qm_t, os.path.join(outdir, "tinyvit_bundle.json"))
    }

    if args.quick:
        with open(os.path.join(outdir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print("quick mode: skipped deit artifacts")
        return

    # ---- DeiT-tiny (paper workload) ---------------------------------------
    dcfg = M.deit_tiny()
    dparams = M.init_params(rng, dcfg)
    dimgs = rng.uniform(0.0, 1.0, (args.calib_batch, dcfg.img_size, dcfg.img_size, 3))
    dtoks = M.patchify(dimgs, dcfg)
    t0 = time.time()
    qm_d = M.build_quantized(dparams, dcfg, dtoks)
    print(f"deit-tiny a4w4 calibration: {time.time()-t0:.1f}s, {qm_d.lut_count()} luts")
    dump_qm_tables(qm_d, os.path.join(outdir, "tables_deit_tiny_a4w4.json"))
    manifest["bundles"]["deit_tiny_bundle"] = export_bundle(
        qm_d, os.path.join(outdir, "deit_tiny_bundle.json")
    )

    for batch in (1, 8):
        info = lower_to_file(
            lambda x: (M.end_to_end_jnp(qm_d, x),),
            [jax.ShapeDtypeStruct((batch, dcfg.tokens, dcfg.patch_dim), jnp.float32)],
            os.path.join(outdir, f"deit_tiny_a4w4_b{batch}.hlo.txt"),
        )
        manifest["artifacts"][f"deit_tiny_a4w4_b{batch}"] = {
            **info,
            "input": [batch, dcfg.tokens, dcfg.patch_dim],
            "output": [batch, dcfg.num_classes],
            "model": "deit-tiny", "precision": "a4w4",
        }
        print(f"deit_tiny_a4w4_b{batch}: {info}")

    # single block through the Pallas kernels
    fn, spec = block_pallas_fn(qm_d, 0)
    info = lower_to_file(fn, [spec], os.path.join(outdir, "deit_tiny_block_pallas.hlo.txt"))
    manifest["artifacts"]["deit_tiny_block_pallas"] = {
        **info,
        "input": [dcfg.tokens, dcfg.dim],
        "output": [dcfg.tokens, dcfg.dim],
        "model": "deit-tiny", "precision": "a4w4", "layer": "block0-pallas",
    }
    print(f"deit_tiny_block_pallas: {info}")

    # A3W3 table set (resource/accuracy analysis; Table 2 A3W3 column)
    dcfg3 = M.deit_tiny(act_bits=3, weight_bits=3)
    qm_d3 = M.build_quantized(dparams, dcfg3, dtoks)
    dump_qm_tables(qm_d3, os.path.join(outdir, "tables_deit_tiny_a3w3.json"))

    # ---- quant report ------------------------------------------------------
    report = {
        "deit_tiny_a4w4": {
            "lut_count": qm_d.lut_count(),
            "input_scale": qm_d.input_q.scale,
            "s0": qm_d.s0,
            "ops_per_inference": dcfg.ops_per_inference,
        },
        "deit_tiny_a3w3": {"lut_count": qm_d3.lut_count()},
        "tinyvit_a4w4": {"lut_count": qm_t.lut_count(), "float_acc": float_acc},
    }
    with open(os.path.join(outdir, "quant_report.json"), "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print("manifest written; artifact build complete")


if __name__ == "__main__":
    main()
