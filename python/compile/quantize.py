"""Quantization scheme of the HG-PIPE dataflow (paper Sec. 2.1, Eq. 4).

Every tensor that crosses a module boundary is a low-bit *integer* tensor
with an attached affine quantizer ``real = (q - zero_point) * scale``.
Weights are quantized symmetrically per-tensor; activations are quantized
by the ReQuant operator, which on the accelerator is a LUT (Sec. 4.4.4) —
here we keep both the exact affine form (this module) and the LUT form
(``tables.py``), and the test suite checks the LUT form tracks this one.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import numerics


@dataclass(frozen=True)
class QuantParams:
    """Affine quantizer: real = (q - zero_point) * scale, q in [qmin, qmax]."""

    scale: float
    zero_point: int
    bits: int
    signed: bool = True

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    def quantize(self, x: np.ndarray) -> np.ndarray:
        q = np.floor(x / self.scale + 0.5).astype(np.int64) + self.zero_point
        # floor(x+0.5) == round-half-away for x>=0 and round-half-up for x<0;
        # use true half-away to match numerics.round_half_away:
        neg = x < 0
        qn = -np.floor(-x / self.scale + 0.5).astype(np.int64) + self.zero_point
        q = np.where(neg, qn, q)
        return np.clip(q, self.qmin, self.qmax).astype(np.int32)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return (q.astype(np.float64) - self.zero_point) * self.scale


def calibrate_symmetric(x: np.ndarray, bits: int) -> QuantParams:
    """Symmetric per-tensor quantizer from observed data (weights)."""
    amax = float(np.max(np.abs(x)))
    if amax == 0.0:
        amax = 1.0
    qmax = (1 << (bits - 1)) - 1
    return QuantParams(scale=amax / qmax, zero_point=0, bits=bits, signed=True)


def calibrate_affine(x: np.ndarray, bits: int, signed: bool = True) -> QuantParams:
    """Affine per-tensor quantizer from observed data (activations)."""
    lo, hi = float(np.min(x)), float(np.max(x))
    if hi <= lo:
        hi = lo + 1.0
    qmin = -(1 << (bits - 1)) if signed else 0
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = (hi - lo) / (qmax - qmin)
    zp = int(numerics.round_half_away(qmin - lo / scale))
    zp = int(np.clip(zp, qmin, qmax))
    return QuantParams(scale=scale, zero_point=zp, bits=bits, signed=signed)


def requant_affine(
    acc: np.ndarray, in_params: QuantParams, out_params: QuantParams
) -> np.ndarray:
    """Exact (non-LUT) ReQuant: dequantize with in_params, requantize.

    This is the float-exact reference the 64-entry ReQuant table
    (Sec. 4.4.4) approximates.
    """
    return out_params.quantize(in_params.dequantize(acc))


@dataclass(frozen=True)
class AccQuant:
    """Quantizer of an integer MM accumulator.

    acc = sum(x_q * w_q) with x affine (scale sx, zp zx) and w symmetric
    (scale sw). real = sx*sw * (acc - zx * sum(w_q)) — the zx correction is
    folded into the per-output-channel bias on the accelerator; we fold it
    the same way, so the accumulator quantizer is pure scale.
    """

    scale: float

    def dequantize(self, acc: np.ndarray) -> np.ndarray:
        return acc.astype(np.float64) * self.scale
