"""HG-PIPE build-time python package: L1 Pallas kernels, L2 JAX model, AOT.

Never imported at runtime — the rust binary consumes only the HLO text and
JSON artifacts this package emits (``make artifacts``).
"""
