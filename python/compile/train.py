"""Tiny-ViT trainer on a procedural 10-class dataset.

The paper evaluates quantization/LUT ablations on ImageNet (DeiT-tiny
74.5% fp32). We do not ship ImageNet or the authors' QAT checkpoints, so
the accuracy-*shape* experiments (Fig. 11a ladder, Fig. 11b ablations) run
on a small ViT trained here on a procedurally generated dataset: ten
texture/shape classes with enough intra-class variation that a float
tiny-ViT reaches high accuracy while the LUT approximations still bite in
the same qualitative order as the paper reports.

CLI:  python -m compile.train --out ../artifacts/tinyvit_params.npz
"""

from __future__ import annotations

import argparse
import math
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from .model import ViTConfig, init_params, patchify, tiny_synth


# ---------------------------------------------------------------------------
# procedural dataset: 10 classes of 32x32 RGB textures
# ---------------------------------------------------------------------------


def synth_images(rng: np.random.Generator, n: int, size: int = 32):
    """Generate n labelled images. Classes:
    0 horizontal stripes, 1 vertical stripes, 2 diagonal stripes,
    3 checkerboard, 4 radial rings, 5 random dots, 6 gradient,
    7 cross, 8 solid+noise, 9 blobs.
    """
    xs = np.zeros((n, size, size, 3), np.float64)
    ys = rng.integers(0, 10, n)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64)
    for i in range(n):
        c = ys[i]
        freq = rng.uniform(0.3, 1.2)
        phase = rng.uniform(0, 2 * math.pi)
        amp = rng.uniform(0.6, 1.0)
        if c == 0:
            img = np.sin(yy * freq + phase)
        elif c == 1:
            img = np.sin(xx * freq + phase)
        elif c == 2:
            img = np.sin((xx + yy) * freq * 0.7 + phase)
        elif c == 3:
            p = max(int(rng.integers(2, 6)), 1)
            img = (((yy // p) + (xx // p)) % 2) * 2.0 - 1.0
        elif c == 4:
            cy, cx = rng.uniform(10, 22, 2)
            rr = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
            img = np.sin(rr * freq + phase)
        elif c == 5:
            img = -np.ones((size, size))
            for _ in range(int(rng.integers(6, 14))):
                py, px = rng.integers(2, size - 2, 2)
                img[py - 1 : py + 2, px - 1 : px + 2] = 1.0
        elif c == 6:
            ang = rng.uniform(0, 2 * math.pi)
            img = (np.cos(ang) * xx + np.sin(ang) * yy) / size * 2 - 1
        elif c == 7:
            img = -np.ones((size, size))
            w = int(rng.integers(2, 5))
            m = size // 2 + int(rng.integers(-4, 5))
            img[m - w : m + w, :] = 1.0
            img[:, m - w : m + w] = 1.0
        elif c == 8:
            img = np.full((size, size), rng.uniform(-0.5, 0.5))
        else:
            img = -np.ones((size, size))
            for _ in range(3):
                cy, cx = rng.uniform(4, size - 4, 2)
                r = rng.uniform(3, 7)
                img = np.maximum(img, np.where((yy - cy) ** 2 + (xx - cx) ** 2 < r * r, 1.0, -1.0))
        img = amp * img + rng.normal(0, 0.15, (size, size))
        # class-dependent colour tint for the channel dimension
        tint = np.array([0.5 + 0.05 * c, 0.5 - 0.03 * c, 0.5 + 0.02 * ((c * 3) % 7)])
        xs[i] = 0.5 + 0.45 * img[..., None] * tint[None, None, :]
    return np.clip(xs, 0.0, 1.0), ys


# ---------------------------------------------------------------------------
# jax float forward (training twin of model.forward_f32)
# ---------------------------------------------------------------------------


def forward_f32_jax(params, tokens, cfg: ViTConfig):
    def ln(x, g, b, eps=1e-6):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + eps) * g + b

    x = tokens @ params["pe_w"] + params["pe_b"]
    h, dh = cfg.heads, cfg.head_dim
    for blk in params["blocks"]:
        n = ln(x, blk["ln1_g"], blk["ln1_b"])
        qkv = n @ blk["qkv_w"] + blk["qkv_b"]
        b, t, _ = qkv.shape
        qkv = jnp.transpose(qkv.reshape(b, t, 3, h, dh), (2, 0, 3, 1, 4))
        q, k, v = qkv[0], qkv[1], qkv[2]
        scores = q @ jnp.transpose(k, (0, 1, 3, 2)) / math.sqrt(dh)
        probs = jax.nn.softmax(scores, axis=-1)
        a = jnp.transpose(probs @ v, (0, 2, 1, 3)).reshape(b, t, cfg.dim)
        x = x + (a @ blk["proj_w"] + blk["proj_b"])
        n2 = ln(x, blk["ln2_g"], blk["ln2_b"])
        hdn = jax.nn.gelu(n2 @ blk["mm1_w"] + blk["mm1_b"], approximate=False)
        x = x + (hdn @ blk["mm2_w"] + blk["mm2_b"])
    n = ln(x, params["ln_f_g"], params["ln_f_b"])
    return n.mean(axis=1) @ params["head_w"] + params["head_b"]


def _to_f32_pytree(params):
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), params)


def _to_np_f64(params):
    return jax.tree_util.tree_map(lambda a: np.asarray(a, np.float64), params)


# ---------------------------------------------------------------------------
# training loop (adam)
# ---------------------------------------------------------------------------


def train(
    cfg: ViTConfig | None = None,
    steps: int = 600,
    batch: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    eval_n: int = 1000,
):
    """Train and return (params_f64_numpy, train_acc, test_acc)."""
    cfg = cfg or tiny_synth()
    rng = np.random.default_rng(seed)
    params = _to_f32_pytree(init_params(rng, cfg))

    def loss_fn(p, toks, ys):
        logits = forward_f32_jax(p, toks, cfg)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=1))

    # hand-rolled adam (no optax dependency needed at build time)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(p, m, v, t, toks, ys):
        loss, g = jax.value_and_grad(loss_fn)(p, toks, ys)
        m = jax.tree_util.tree_map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree_util.tree_map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        mh = jax.tree_util.tree_map(lambda mm: mm / (1 - b1**t), m)
        vh = jax.tree_util.tree_map(lambda vv: vv / (1 - b2**t), v)
        p = jax.tree_util.tree_map(
            lambda pp, mm, vv: pp - lr * mm / (jnp.sqrt(vv) + eps), p, mh, vh
        )
        return p, m, v, loss

    losses = []
    for t in range(1, steps + 1):
        imgs, ys = synth_images(rng, batch)
        toks = jnp.asarray(patchify(imgs, cfg), jnp.float32)
        params, m, v, loss = step(params, m, v, jnp.float32(t), toks, jnp.asarray(ys))
        losses.append(float(loss))
        if t % 100 == 0:
            print(f"step {t:4d}  loss {np.mean(losses[-100:]):.4f}")

    # eval
    imgs, ys = synth_images(np.random.default_rng(seed + 1), eval_n)
    toks = jnp.asarray(patchify(imgs, cfg), jnp.float32)
    acc = float(
        (jnp.argmax(forward_f32_jax(params, toks, cfg), axis=1) == jnp.asarray(ys)).mean()
    )
    print(f"float eval accuracy: {acc:.4f}")
    return _to_np_f64(params), losses, acc


def eval_accuracy(predict_fn, cfg: ViTConfig, n: int = 1000, seed: int = 1) -> float:
    """Accuracy of an arbitrary tokens->logits callable on the synth set."""
    imgs, ys = synth_images(np.random.default_rng(seed), n)
    toks = patchify(imgs, cfg)
    logits = predict_fn(toks)
    return float((np.asarray(logits).argmax(axis=1) == ys).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/tinyvit_params.pkl")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, losses, acc = train(steps=args.steps, seed=args.seed)
    with open(args.out, "wb") as f:
        pickle.dump({"params": params, "losses": losses, "float_acc": acc}, f)
    print(f"wrote {args.out} (float acc {acc:.4f})")


if __name__ == "__main__":
    main()
