//! Figure 12: the hybrid-grained pipeline timing diagram, cycle-accurate.
//!
//! Run: `cargo run --release --example timing_diagram [-- --images 3]`

use hgpipe::arch::parallelism::design_network;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig};

fn main() {
    let images: u64 = std::env::args()
        .skip_while(|a| a != "--images")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);
    let p = sim::build_vit(&d, &cfg, Paradigm::Hybrid, SimConfig::matched(&d, &cfg));
    let t0 = std::time::Instant::now();
    let r = sim::run(&p, images, 50_000_000);
    println!("{}", sim::trace::render_gantt(&r, 110));
    let s = sim::trace::summarize(&r, 425e6).expect("completes");
    println!("simulated {} cycles in {:?}", r.cycles, t0.elapsed());
    println!("                         ours        paper");
    println!("stable II            {:>9}       57,624", s.stable_ii);
    println!("Image1 total cycles  {:>9}      824,843", s.first_image_cycles);
    println!("latency (ms)         {:>9.3}        0.136", s.latency_ms);
    println!("ideal img/s          {:>9.0}        7,353", s.ideal_fps);

    // busiest/stalliest stages — useful for understanding the pipeline
    println!("\nper-stage utilization extremes:");
    let mut utils: Vec<(f64, String)> = r
        .stage_specs
        .iter()
        .zip(&r.stage_states)
        .map(|(sp, st)| (st.busy_cycles as f64 / r.cycles as f64, sp.name.clone()))
        .collect();
    utils.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    for (u, n) in utils.iter().take(3) {
        println!("  busiest: {n:<22} {:.1}%", u * 100.0);
    }
    for (u, n) in utils.iter().rev().take(3) {
        println!("  idlest : {n:<22} {:.1}%", u * 100.0);
    }
}
