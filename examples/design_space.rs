//! Design-space exploration: sweep networks x precisions x platforms
//! through the parallelism designer + deployment model (the ablation
//! DESIGN.md calls out: what the hand-crafted Table-1 point trades).
//!
//! Run: `cargo run --release --example design_space`
//! (`-- --smoke` trims every sweep to its smallest point — one network,
//! two precisions, two TP values, one deployment — for CI/quick demos)

use hgpipe::arch::parallelism::{balance_target, design_network};
use hgpipe::metrics::{datapath_luts, deploy};
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::platform::Fpga;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let networks = if smoke {
        vec![ViTConfig::tiny_synth()]
    } else {
        vec![ViTConfig::tiny_synth(), ViTConfig::deit_tiny(), ViTConfig::deit_small()]
    };
    let precisions: &[Precision] = if smoke {
        &[Precision::A8W8, Precision::A4W4]
    } else {
        &[Precision::A8W8, Precision::A4W4, Precision::A4W3, Precision::A3W3]
    };
    println!("=== designer sweep: network x precision ===");
    println!(
        "{:<12} {:<6} {:>9} {:>10} {:>11} {:>12}",
        "network", "prec", "MACs", "wBRAMs", "target II", "datapath LUT"
    );
    for cfg in networks {
        for &prec in precisions {
            let d = design_network(&cfg, prec, 2);
            println!(
                "{:<12} {:<6} {:>9} {:>10} {:>11} {:>12}",
                cfg.name,
                prec.label(),
                d.total_macs(),
                d.total_brams(),
                d.target_ii,
                datapath_luts(&d),
            );
        }
    }

    println!("\n=== TP sweep: balance target vs token parallelism (deit-tiny) ===");
    let cfg = ViTConfig::deit_tiny();
    let tps: &[u64] = if smoke { &[1, 2] } else { &[1, 2, 4, 7] };
    for &tp in tps {
        let d = design_network(&cfg, Precision::A4W3, tp);
        println!(
            "TP={tp}: target II {:>7}  MACs {:>7}  ideal fps@425MHz {:>6.0}",
            balance_target(&cfg, tp),
            d.total_macs(),
            425e6 / d.accelerator_ii() as f64
        );
    }

    println!("\n=== deployment sweep: what fits where ===");
    println!(
        "{:<12} {:<6} {:<8} {:>6} {:>8} {:>9} {:>8}",
        "network", "prec", "device", "scale", "FPS", "GOPs", "GOPs/kLUT"
    );
    let deployments = if smoke {
        vec![(ViTConfig::deit_tiny(), Precision::A4W4, Fpga::zcu102(), 375e6)]
    } else {
        vec![
            (ViTConfig::deit_tiny(), Precision::A4W4, Fpga::zcu102(), 375e6),
            (ViTConfig::deit_tiny(), Precision::A4W4, Fpga::vck190(), 425e6),
            (ViTConfig::deit_tiny(), Precision::A3W3, Fpga::vck190(), 425e6),
            (ViTConfig::deit_small(), Precision::A3W3, Fpga::vck190(), 350e6),
            (ViTConfig::deit_small(), Precision::A4W4, Fpga::vck190(), 350e6),
        ]
    };
    for (cfg, prec, fpga, freq) in deployments {
        let r = deploy(&cfg, prec, &fpga, freq);
        println!(
            "{:<12} {:<6} {:<8} {:>6} {:>8.0} {:>9.0} {:>8.2}",
            cfg.name,
            prec.label(),
            fpga.name,
            r.scale,
            r.fps,
            r.gops,
            r.gops_per_klut()
        );
    }
}
