//! Quickstart: the three layers in one page.
//!
//! 1. the parallelism designer produces the paper's Table-1 design;
//! 2. the cycle-accurate simulator reproduces the Fig.-12 timing;
//! 3. the PJRT runtime loads the AOT-compiled quantized ViT
//!    (`make artifacts` first) and classifies a synthetic image.
//!
//! Run: `cargo run --release --example quickstart`

use hgpipe::arch::parallelism::design_network;
use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::runtime::{BackendKind, RuntimeConfig};
use hgpipe::sim::{self, builder::Paradigm, SimConfig};
use hgpipe::util::prng::Prng;

fn main() -> hgpipe::Result<()> {
    // ---- 1. design ---------------------------------------------------------
    let cfg = ViTConfig::deit_tiny();
    let design = design_network(&cfg, Precision::A4W3, 2);
    println!(
        "[design] {}: {} modules, {} MAC units, target II {}",
        cfg.name,
        design.modules.len(),
        design.total_macs(),
        design.target_ii
    );

    // ---- 2. simulate -------------------------------------------------------
    let pipeline =
        sim::build_vit(&design, &cfg, Paradigm::Hybrid, SimConfig::matched(&design, &cfg));
    let r = sim::run(&pipeline, 3, 5_000_000);
    let s = sim::trace::summarize(&r, 425e6).expect("sim completes");
    println!(
        "[sim]    stable II {} cycles -> {:.0} img/s ideal at 425 MHz (paper: 57624 -> 7353)",
        s.stable_ii, s.ideal_fps
    );

    // ---- 3. serve ----------------------------------------------------------
    let Some(dir) = Manifest::discover() else {
        println!("[serve]  no artifacts found — run `make artifacts` for the serving demo");
        return Ok(());
    };
    let manifest = Manifest::load(&dir)?;
    let model = "tiny-synth"; // small and fast; use deit-tiny for the full net
    // explicit 2-lane persistent fabric (None = HGPIPE_LANES, then all
    // cores); the workers are created here, once, and joined when the
    // server drops
    let config = RuntimeConfig::new(BackendKind::Interpreter).with_lanes(Some(2));
    let server = ModelServer::start_with_config(&manifest, model, 2, config)?;
    let mut rng = Prng::new(1);
    let image: Vec<f32> = (0..server.tokens_per_image()).map(|_| rng.f64() as f32).collect();
    let reply = server.submit(image)?.recv()??;
    println!(
        "[serve]  '{}' classified one image as class {} in {:?}",
        model, reply.argmax, reply.latency
    );
    Ok(())
}
