//! End-to-end serving driver (the EXPERIMENTS.md §E2E workload).
//!
//! Serves the trained tiny-ViT through the coordinator's multi-model
//! [`Router`]: a replicated executor fleet sharing one immutable
//! `ModelArtifact`, accuracy on the real eval batch, then a **mid-stream
//! hot swap** — requests keep arriving while the model is swapped to a
//! fresh version, and drain-then-swap delivers every one of them exactly
//! once (reply or explicit failure, zero silent drops). When the full
//! DeiT-tiny AOT artifacts are present the same router serves them too —
//! python nowhere on the path.
//!
//! Run: `cargo run --release --example serve_e2e [-- --deit-requests 32]`

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::Router;
use hgpipe::runtime::RuntimeConfig;
use hgpipe::util::json::Json;
use hgpipe::util::prng::Prng;

fn main() -> hgpipe::Result<()> {
    let deit_requests: usize = std::env::args()
        .skip_while(|a| a != "--deit-requests")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let dir = Manifest::discover()
        .ok_or_else(|| anyhow::anyhow!("no artifacts found — run `make artifacts` first"))?;
    let manifest = Manifest::load(&dir)?;
    let config = RuntimeConfig::default().with_replicas(Some(2));
    let router = Router::start(&manifest, &["tiny-synth".to_string()], 2, config)?;

    // ---- phase 1: accuracy on the real eval batch (tiny-ViT) --------------
    println!("=== phase 1: tiny-ViT accuracy (real trained model, 512 eval images) ===");
    let (tokens, labels, shape) = load_eval_set(&dir)?;
    let per = shape[1] * shape[2];
    let n_imgs = labels.len();
    let tiny = router.server("tiny-synth").expect("router started tiny-synth");
    if let Some(a) = tiny.artifact() {
        println!(
            "one shared artifact: {:.2} MiB across {} replica(s) ({} Arc refs)",
            a.footprint_bytes() as f64 / (1024.0 * 1024.0),
            tiny.replicas(),
            a.strong_count()
        );
    }
    let images: Vec<Vec<f32>> = tokens.chunks(per).map(|c| c.to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = router.infer_all("tiny-synth", images)?;
    let correct = responses.iter().zip(&labels).filter(|(r, &l)| r.argmax == l as usize).count();
    let dt = t0.elapsed();
    println!(
        "accuracy {}/{} = {:.2}%   throughput {:.0} img/s",
        correct,
        labels.len(),
        100.0 * correct as f64 / labels.len() as f64,
        labels.len() as f64 / dt.as_secs_f64()
    );
    drop(tiny); // release the fleet handle so the swap below can drain it

    // ---- phase 2: hot swap mid-stream (drain-then-swap) -------------------
    println!("\n=== phase 2: hot swap with requests in flight ===");
    let swap_requests = 64usize;
    let mut rxs = Vec::with_capacity(swap_requests);
    for i in 0..swap_requests {
        if i == swap_requests / 2 {
            // half the traffic is queued or in flight on v1; the swap
            // routes the rest to a freshly loaded v2 while v1 drains
            let v = router.swap(&manifest, "tiny-synth", 2, config)?;
            println!("swapped tiny-synth to v{v} mid-stream");
        }
        let img = tokens[(i % n_imgs) * per..(i % n_imgs + 1) * per].to_vec();
        // a submit racing the closing queue errs explicitly — resubmit
        // once and it lands on the new version; nothing is dropped
        let rx = match router.submit("tiny-synth", img.clone()) {
            Ok(rx) => rx,
            Err(_) => router.submit("tiny-synth", img)?,
        };
        rxs.push(rx);
    }
    let (mut answered, mut failed) = (0usize, 0usize);
    for rx in rxs {
        match rx.recv().expect("every accepted request gets exactly one reply") {
            Ok(_) => answered += 1,
            Err(_) => failed += 1,
        }
    }
    println!(
        "{answered} answered + {failed} explicitly failed = {} submitted (zero silent drops)",
        answered + failed
    );
    anyhow::ensure!(answered + failed == swap_requests, "a request vanished across the swap");
    for (v, m) in router.version_metrics("tiny-synth")? {
        println!("  tiny-synth v{v}: {}", m.summary());
    }

    // ---- phase 3: DeiT-tiny latency/throughput (full paper network) -------
    println!("\n=== phase 3: DeiT-tiny serving ({deit_requests} requests, batch variants 1+8) ===");
    if manifest.bundle_for("deit-tiny").is_none() && manifest.variants("deit-tiny").is_empty() {
        println!("(no deit-tiny artifacts — run a full `make artifacts` for phases 3-4)");
        return Ok(());
    }
    // the zoo grows hot: the same router takes a second model without
    // touching the one already serving
    router.load(&manifest, "deit-tiny", 4, RuntimeConfig::default())?;
    let mut rng = Prng::new(11);
    let n_tok = router.server("deit-tiny").expect("just loaded").tokens_per_image();
    let imgs: Vec<Vec<f32>> =
        (0..deit_requests).map(|_| (0..n_tok).map(|_| rng.f64() as f32).collect()).collect();
    let t0 = std::time::Instant::now();
    let responses = router.infer_all("deit-tiny", imgs)?;
    let dt = t0.elapsed();
    println!(
        "{} inferences in {:.2?} = {:.2} img/s (CPU; the FPGA-cycle model puts the fabric at 7139 img/s)",
        responses.len(),
        dt,
        responses.len() as f64 / dt.as_secs_f64()
    );
    for line in router.metrics_lines() {
        println!("{line}");
    }

    // batch-1 vs batch-8 must agree numerically on identical input
    println!("\n=== phase 4: batch-variant consistency ===");
    let probe: Vec<f32> = (0..n_tok).map(|_| rng.f64() as f32).collect();
    let single = router.submit("deit-tiny", probe.clone())?.recv()??;
    let mut batch: Vec<Vec<f32>> = vec![probe; 8];
    for extra in batch.iter_mut().skip(1) {
        for v in extra.iter_mut() {
            *v = rng.f64() as f32;
        }
    }
    let replies = router.infer_all("deit-tiny", batch)?;
    let drift = single
        .logits
        .iter()
        .zip(&replies[0].logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logit drift| between batch-1 and batch-8 paths: {drift:e}");
    anyhow::ensure!(drift < 1e-3, "batch variants disagree");
    println!("OK");
    Ok(())
}

fn load_eval_set(dir: &std::path::Path) -> hgpipe::Result<(Vec<f32>, Vec<u8>, [usize; 3])> {
    let v = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)
        .map_err(|e| anyhow::anyhow!(e))?;
    let es = v.get("eval_set").ok_or_else(|| anyhow::anyhow!("no eval_set in manifest"))?;
    let sh: Vec<usize> = es
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as usize)
        .collect();
    let tokens_raw = std::fs::read(dir.join(es.get("tokens").unwrap().as_str().unwrap()))?;
    let labels = std::fs::read(dir.join(es.get("labels").unwrap().as_str().unwrap()))?;
    let tokens: Vec<f32> = tokens_raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok((tokens, labels, [sh[0], sh[1], sh[2]]))
}
