//! End-to-end serving driver (the EXPERIMENTS.md §E2E workload).
//!
//! Loads the trained tiny-ViT *and* the full DeiT-tiny AOT artifacts,
//! serves batched requests through the coordinator (dynamic batcher +
//! PJRT executor), reports latency percentiles / throughput / accuracy —
//! proving all three layers compose with python nowhere on the path.
//!
//! Run: `cargo run --release --example serve_e2e [-- --deit-requests 32]`

use hgpipe::artifacts::Manifest;
use hgpipe::coordinator::ModelServer;
use hgpipe::util::json::Json;
use hgpipe::util::prng::Prng;

fn main() -> hgpipe::Result<()> {
    let deit_requests: usize = std::env::args()
        .skip_while(|a| a != "--deit-requests")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let dir = Manifest::discover()
        .ok_or_else(|| anyhow::anyhow!("no artifacts found — run `make artifacts` first"))?;
    let manifest = Manifest::load(&dir)?;

    // ---- phase 1: accuracy on the real eval batch (tiny-ViT) --------------
    println!("=== phase 1: tiny-ViT accuracy (real trained model, 512 eval images) ===");
    let (tokens, labels, shape) = load_eval_set(&dir)?;
    let tiny = ModelServer::start(&manifest, "tiny-synth", 2)?;
    let per = shape[1] * shape[2];
    let images: Vec<Vec<f32>> = tokens.chunks(per).map(|c| c.to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = tiny.infer_all(images)?;
    let correct = responses.iter().zip(&labels).filter(|(r, &l)| r.argmax == l as usize).count();
    let dt = t0.elapsed();
    println!(
        "accuracy {}/{} = {:.2}%   throughput {:.0} img/s",
        correct,
        labels.len(),
        100.0 * correct as f64 / labels.len() as f64,
        labels.len() as f64 / dt.as_secs_f64()
    );
    println!("{}", tiny.metrics.lock().unwrap().summary());
    drop(tiny);

    // ---- phase 2: DeiT-tiny latency/throughput (full paper network) -------
    println!("\n=== phase 2: DeiT-tiny serving ({deit_requests} requests, batch variants 1+8) ===");
    if manifest.bundle_for("deit-tiny").is_none() && manifest.variants("deit-tiny").is_empty() {
        println!("(no deit-tiny artifacts — run a full `make artifacts` for phases 2-3)");
        return Ok(());
    }
    let deit = ModelServer::start(&manifest, "deit-tiny", 4)?;
    let mut rng = Prng::new(11);
    let n_tok = deit.tokens_per_image();
    let imgs: Vec<Vec<f32>> =
        (0..deit_requests).map(|_| (0..n_tok).map(|_| rng.f64() as f32).collect()).collect();
    let t0 = std::time::Instant::now();
    let responses = deit.infer_all(imgs)?;
    let dt = t0.elapsed();
    println!(
        "{} inferences in {:.2?} = {:.2} img/s (CPU PJRT; the FPGA-cycle model puts the fabric at 7139 img/s)",
        responses.len(),
        dt,
        responses.len() as f64 / dt.as_secs_f64()
    );
    println!("{}", deit.metrics.lock().unwrap().summary());

    // batch-1 vs batch-8 must agree numerically on identical input
    println!("\n=== phase 3: batch-variant consistency ===");
    let probe: Vec<f32> = (0..n_tok).map(|_| rng.f64() as f32).collect();
    let single = deit.submit(probe.clone())?.recv()??;
    let mut batch: Vec<Vec<f32>> = vec![probe; 8];
    for extra in batch.iter_mut().skip(1) {
        for v in extra.iter_mut() {
            *v = rng.f64() as f32;
        }
    }
    let replies = deit.infer_all(batch)?;
    let drift = single
        .logits
        .iter()
        .zip(&replies[0].logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logit drift| between batch-1 and batch-8 paths: {drift:e}");
    anyhow::ensure!(drift < 1e-3, "batch variants disagree");
    println!("OK");
    Ok(())
}

fn load_eval_set(dir: &std::path::Path) -> hgpipe::Result<(Vec<f32>, Vec<u8>, [usize; 3])> {
    let v = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)
        .map_err(|e| anyhow::anyhow!(e))?;
    let es = v.get("eval_set").ok_or_else(|| anyhow::anyhow!("no eval_set in manifest"))?;
    let sh: Vec<usize> = es
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as usize)
        .collect();
    let tokens_raw = std::fs::read(dir.join(es.get("tokens").unwrap().as_str().unwrap()))?;
    let labels = std::fs::read(dir.join(es.get("labels").unwrap().as_str().unwrap()))?;
    let tokens: Vec<f32> = tokens_raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok((tokens, labels, [sh[0], sh[1], sh[2]]))
}
