//! Figure 11a/11b: the DSP-reduction ladder with its accuracy trajectory,
//! and the per-technique ablations, combining the rust resource model
//! with the accuracy measurements from the python build (which ran the
//! bit-exact integer model over the trained tiny-ViT).
//!
//! Run: `cargo run --release --example accuracy_ladder`
//! (`-- --smoke` prints the DSP ladder only — no artifact reads — so CI
//! and quick demos complete in well under a second)

use hgpipe::arch::dsp::dsp_ladder;
use hgpipe::arch::parallelism::design_network;
use hgpipe::model::{Precision, ViTConfig};
use hgpipe::util::json::Json;

fn main() -> hgpipe::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ViTConfig::deit_tiny();
    let d = design_network(&cfg, Precision::A4W3, 2);

    let path = std::path::Path::new("artifacts/accuracy_ladder.json");
    let acc = if smoke {
        None // smoke mode: resource ladder only, no artifact dependency
    } else if path.exists() {
        Some(Json::parse(&std::fs::read_to_string(path)?).map_err(|e| anyhow::anyhow!(e))?)
    } else {
        println!("(accuracy_ladder.json missing — showing DSP ladder only; run `make artifacts`)");
        None
    };

    println!("=== Figure 11a: DSP usage ladder (DeiT-tiny design) ===");
    println!("{:<40} {:>10} {:>12}", "step", "DSPs ours", "DSPs paper");
    for s in dsp_ladder(&d) {
        println!(
            "{:<40} {:>10} {:>12}",
            s.name,
            s.dsps,
            s.paper_dsps.map(|p| p.to_string()).unwrap_or_default()
        );
    }

    if let Some(acc) = &acc {
        // the accuracy trajectory (tiny-ViT substitution; see DESIGN.md)
        for prec in ["a4w4", "a3w3"] {
            let Some(ladder) = acc.get(prec).and_then(|p| p.get("ladder")) else { continue };
            println!("\n=== accuracy trajectory [{prec}] (tiny-ViT, synthetic 10-class) ===");
            for step in [
                "fp32",
                "lut_mac",
                "pot_lut",
                "+inverted_exp",
                "+requant_calib",
                "+gelu_calib",
                "+segmented_recip",
            ] {
                if let Some(a) = ladder.get(step).and_then(|x| x.as_f64()) {
                    println!("  {step:<18} {:.3}", a);
                }
            }
        }
        println!("\n=== Figure 11b: ablations (accuracy delta vs full pipeline) ===");
        for prec in ["a4w4", "a3w3"] {
            let Some(p) = acc.get(prec) else { continue };
            let full = p
                .get("ladder")
                .and_then(|l| l.get("+segmented_recip"))
                .and_then(|x| x.as_f64())
                .unwrap_or(f64::NAN);
            println!("[{prec}] full = {full:.3}");
            if let Some(abl) = p.get("ablation").and_then(|a| a.as_obj()) {
                for (name, v) in abl {
                    let a = v.as_f64().unwrap_or(f64::NAN);
                    println!("  {name:<22} {a:.3} ({:+.3})", a - full);
                }
            }
        }
    }
    Ok(())
}
