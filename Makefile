# Build entry points for the HG-PIPE reproduction.
#
# `make artifacts` is the target the rust tests and doc comments
# reference: the python AOT pipeline (train / calibrate / quantize) emits
# HLO text, LUT tables, interpreter bundles, and the eval batch into
# rust/artifacts/. The committed golden fixture under
# rust/artifacts/golden/ is never touched by it — regenerate that with
# `make golden` (and commit bundle + logits together: they are a matched
# set).

ARTIFACTS := rust/artifacts

.PHONY: build test test-rust test-python artifacts golden bench-json bench-json-smoke bench-check trace-smoke http-smoke

build:
	cargo build --release

# Interpreter fabric throughput report -> BENCH_interpreter.json at the
# repo root: scalar baseline vs spawn-per-region pool vs the persistent
# worker fabric, a lane-scaling sweep (1/2/4/available), the GEMM
# microkernel-vs-naive speedup (dense + sparse), and serial + pooled
# per-op breakdowns. Field docs live in README.md. Lane precedence:
# `--lanes` (after `--`) > HGPIPE_LANES > max(4, available cores). The
# path is absolute because cargo runs bench binaries with cwd = the
# package dir (rust/), not the invocation dir. The smoke variant is what
# CI runs on every push.
bench-json:
	cargo bench --bench interpreter -- --json $(CURDIR)/BENCH_interpreter.json

bench-json-smoke:
	cargo bench --bench interpreter -- --json $(CURDIR)/BENCH_interpreter.json --smoke

# CI perf-regression gate: schema-validate the freshly generated
# BENCH_interpreter.json (every README-documented key incl. the
# scale_out section) and compare the pooled/pipeline img/s headline
# numbers against the committed floors in BENCH_baseline.json (generous
# tolerance — catches catastrophic regressions and schema drift, not
# runner noise). Run after bench-json[-smoke].
bench-check:
	cargo run --release --bin bench_check -- \
	  --bench $(CURDIR)/BENCH_interpreter.json \
	  --baseline $(CURDIR)/BENCH_baseline.json

# Telemetry smoke: serve a small closed-loop workload with --trace on
# (pipeline mode, so stage residency and stall spans are exercised too),
# then validate the emitted Chrome-trace JSONL with trace_check:
# well-formedness of every line, span nesting per thread lane,
# exactly-one admission per request id, and non-trivial coverage.
trace-smoke:
	cargo run --release --bin hgpipe -- serve --requests 32 \
	  --pipeline --trace $(CURDIR)/TRACE_smoke.jsonl
	cargo run --release --bin trace_check -- --trace $(CURDIR)/TRACE_smoke.jsonl

# Network front door smoke: boot the real binary with
# `serve --http 127.0.0.1:0` on the golden fixture, POST every golden
# image over the socket (bit-exact reply check), line-parse /metrics
# against the pinned Prometheus families, hit /healthz, then restart
# with `--queue-cap 1` + a stall fault and verify overload answers 429
# with the shed attributed to source="http". The hgpipe binary is built
# first because the harness execs it as a sibling of its own executable.
http-smoke:
	cargo build --release --bin hgpipe
	cargo run --release --bin http_smoke

test: test-rust test-python

test-rust: build
	cargo test -q

test-python:
	cd python && python -m pytest tests -q

artifacts:
	cd python && python -m compile.aot --out ../$(ARTIFACTS)

golden:
	cd python && python -m compile.export --out ../$(ARTIFACTS)/golden
